//! Interleaving tests: the runtime/gateway synchronization protocols
//! driven through the deterministic schedule explorer
//! (`analysis::explore`). Run with:
//!
//! ```text
//! cargo test --features interleave --test interleave
//! ```
//!
//! Two kinds of test live here:
//!
//! * **Protocol models** — the exact lock/condvar/atomic shape of a
//!   production protocol (the global runtime's task-reclaim barrier,
//!   the panic stash) rebuilt over the instrumented shims, in both the
//!   real shape (must pass every explored schedule) and a deliberately
//!   broken shape (the explorer must find the failing schedule). The
//!   broken variants are the harness's own regression tests: if a
//!   refactor ever blinds the explorer, these fail first.
//! * **Real-code drives** — the actual `ReplySlot`/`Ticket` rendezvous
//!   and the actual `QueueState`/`pop_next` admission queue (via the
//!   feature-gated `gateway::model` re-exports) run under the explorer,
//!   so the invariants hold for the shipped code, not a copy of it.

#![cfg(feature = "interleave")]

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use marsellus::analysis::explore::{
    explore, explore_collect, spawn, ExploreOpts,
};
use marsellus::analysis::sync::{AtomicUsize, Condvar, Mutex};
use marsellus::dnn::{NetworkSpec, PrecisionConfig};
use marsellus::gateway::model::{
    cancel_queued, pop_next, release_inflight, shed_expired, QueueState,
    ReplySlot, Request,
};
use marsellus::gateway::{Completed, Priority, ServeError, Ticket};
use marsellus::power::OperatingPoint;

fn opts(max_schedules: usize) -> ExploreOpts {
    ExploreOpts { max_schedules, ..ExploreOpts::default() }
}

// ---------------------------------------------------------------------
// Task-reclaim barrier (runtime/global.rs JobCore protocol)
// ---------------------------------------------------------------------

/// Model of `JobCore`: the task slot, the `done` barrier counter
/// guarded-by-convention under the state mutex, and the wakeup condvar.
/// The task stand-in is an `Arc<()>` so `Arc::strong_count` observes
/// clone lifetime exactly like the real `GlobalTask`.
struct ReclaimModel {
    task: Mutex<Option<Arc<()>>>,
    done: AtomicUsize,
    n: usize,
    state: Mutex<()>,
    barrier: Condvar,
}

impl ReclaimModel {
    fn new(n: usize) -> Arc<Self> {
        Arc::new(Self {
            task: Mutex::new(Some(Arc::new(()))),
            done: AtomicUsize::new(0),
            n,
            state: Mutex::new(()),
            barrier: Condvar::new(),
        })
    }

    /// One worker serving one item, the shipped shape: clone the task
    /// out, run it, drop the clone, THEN count the item done under the
    /// state mutex (`run_chunk`).
    fn run_item_correct(&self) {
        let task = self
            .task
            .lock()
            .unwrap()
            .clone()
            .expect("task reclaimed before barrier");
        // (the item body would run here)
        drop(task);
        let _g = self.state.lock().unwrap();
        self.done.fetch_add(1, Ordering::SeqCst);
        self.barrier.notify_all();
    }

    /// The seeded bug: count `done` (and wake the submitter) while the
    /// task clone is still alive. A submitter that reclaims on
    /// `done == n` can then observe a surviving clone — the exact
    /// soundness hole the real protocol's drop-before-count closes.
    fn run_item_broken(&self) {
        let task = self
            .task
            .lock()
            .unwrap()
            .clone()
            .expect("task reclaimed before barrier");
        {
            let _g = self.state.lock().unwrap();
            self.done.fetch_add(1, Ordering::SeqCst);
            self.barrier.notify_all();
        }
        drop(task); // too late: the barrier may already have resolved
    }

    /// The submitter side of `scatter`: wait out the barrier under the
    /// state mutex, then reclaim the task and assert it holds the last
    /// reference — the invariant `scatter_scoped`'s transmute rests on.
    fn reclaim_after_barrier(&self) {
        let mut g = self.state.lock().unwrap();
        while self.done.load(Ordering::SeqCst) < self.n {
            g = self.barrier.wait(g).unwrap();
        }
        drop(g);
        let task = self
            .task
            .lock()
            .unwrap()
            .take()
            .expect("invariant: task reclaimed exactly once");
        assert_eq!(
            Arc::strong_count(&task),
            1,
            "invariant: task clone survived the barrier"
        );
    }
}

fn drive_reclaim(model: &Arc<ReclaimModel>, broken: bool) {
    let mut workers = Vec::new();
    for _ in 0..model.n {
        let m = model.clone();
        workers.push(spawn(move || {
            if broken {
                m.run_item_broken();
            } else {
                m.run_item_correct();
            }
        }));
    }
    model.reclaim_after_barrier();
    for w in workers {
        w.join();
    }
}

/// The shipped drop-before-count protocol: every explored schedule
/// reclaims exactly once, after the barrier, with no clone surviving.
#[test]
fn reclaim_protocol_holds_under_all_schedules() {
    let report = explore(opts(20_000), || {
        let model = ReclaimModel::new(2);
        drive_reclaim(&model, false);
    });
    assert!(report.schedules > 10, "trivial exploration: {report:?}");
}

/// Acceptance gate: the deliberately broken variant (count before
/// drop) must fail in some explored schedule — proof the explorer can
/// see the bug class the real protocol is defending against.
#[test]
fn reclaim_counting_before_drop_is_caught() {
    let err = explore_collect(opts(20_000), || {
        let model = ReclaimModel::new(2);
        drive_reclaim(&model, true);
    })
    .expect_err("explorer must catch the premature done-count");
    assert!(
        err.contains("task clone survived the barrier"),
        "unexpected failure: {err}"
    );
}

// ---------------------------------------------------------------------
// Panic stash (JobCore::panic — first panic wins, resumed exactly once)
// ---------------------------------------------------------------------

/// Model of the pool/runtime panic protocol: panicking items stash
/// their payload (first wins) and still count done; the submitter
/// resumes the stash exactly once, after the barrier.
#[test]
fn panic_stash_resumes_exactly_once() {
    let report = explore(opts(20_000), || {
        struct PanicModel {
            stash: Mutex<Option<&'static str>>,
            done: AtomicUsize,
            state: Mutex<()>,
            barrier: Condvar,
        }
        let m = Arc::new(PanicModel {
            stash: Mutex::new(None),
            done: AtomicUsize::new(0),
            state: Mutex::new(()),
            barrier: Condvar::new(),
        });
        let mut workers = Vec::new();
        for name in ["tile 3 exploded", "tile 7 exploded"] {
            let m = m.clone();
            workers.push(spawn(move || {
                // catch_unwind equivalent: the panic becomes a stash
                // entry, first one wins, the item still counts done
                {
                    let mut stash = m.stash.lock().unwrap();
                    if stash.is_none() {
                        *stash = Some(name);
                    }
                }
                let _g = m.state.lock().unwrap();
                m.done.fetch_add(1, Ordering::SeqCst);
                m.barrier.notify_all();
            }));
        }
        // submitter: barrier, then resume the stash exactly once
        {
            let mut g = m.state.lock().unwrap();
            while m.done.load(Ordering::SeqCst) < 2 {
                g = m.barrier.wait(g).unwrap();
            }
        }
        let first = m.stash.lock().unwrap().take();
        assert!(
            first.is_some(),
            "invariant: a stashed panic is resumed after the barrier"
        );
        let second = m.stash.lock().unwrap().take();
        assert!(
            second.is_none(),
            "invariant: panics are resumed exactly once"
        );
        for w in workers {
            w.join();
        }
    });
    assert!(report.schedules > 10, "trivial exploration: {report:?}");
}

// ---------------------------------------------------------------------
// Ticket rendezvous (real ReplySlot under the explorer)
// ---------------------------------------------------------------------

fn completed(finish_seq: u64) -> Completed {
    Completed {
        results: Vec::new(),
        queued: Duration::ZERO,
        service: Duration::ZERO,
        deadline_missed: false,
        finish_seq,
    }
}

/// The real `ReplySlot`/`Ticket` rendezvous: fill racing wait delivers
/// the result exactly once in every explored schedule — no ticket is
/// woken without a result, no result is lost.
#[test]
fn real_reply_slot_delivers_under_all_schedules() {
    let report = explore(opts(20_000), || {
        let slot = ReplySlot::new();
        let filler = slot.clone();
        let dispatcher = spawn(move || {
            filler.fill(Ok(completed(41)));
        });
        let out = Ticket::for_model(1, slot)
            .wait()
            .expect("filled Ok must arrive as Ok");
        assert_eq!(out.finish_seq, 41, "wrong result delivered");
        dispatcher.join();
    });
    assert!(report.schedules > 1, "trivial exploration: {report:?}");
}

/// Counter-model: a rendezvous with the two classic bugs — notify
/// before store, and a single-check (`if`, not `while`) wait. The
/// explorer must find a schedule where the waiter wakes without a
/// result or sleeps through a lost wakeup.
#[test]
fn broken_rendezvous_is_caught() {
    struct BrokenSlot {
        result: Mutex<Option<u32>>,
        ready: Condvar,
    }
    let err = explore_collect(opts(20_000), || {
        let slot = Arc::new(BrokenSlot {
            result: Mutex::new(None),
            ready: Condvar::new(),
        });
        let filler = slot.clone();
        let h = spawn(move || {
            filler.ready.notify_all(); // BROKEN: notify before store
            *filler.result.lock().unwrap() = Some(99);
        });
        let mut g = slot.result.lock().unwrap();
        if g.is_none() {
            // BROKEN: single check — a wakeup is trusted blindly
            g = slot.ready.wait(g).unwrap();
        }
        let v = g.take().expect("woken without a result");
        assert_eq!(v, 99);
        drop(g);
        h.join();
    })
    .expect_err("explorer must catch the broken rendezvous");
    assert!(
        err.contains("woken without a result") || err.contains("deadlock"),
        "unexpected failure: {err}"
    );
}

// ---------------------------------------------------------------------
// Shutdown vs. submit (real QueueState + pop_next under the explorer)
// ---------------------------------------------------------------------

fn model_request(id: u64, priority: Priority) -> Request {
    Request {
        id,
        tenant: "t".into(),
        spec: NetworkSpec::new("kws", PrecisionConfig::Mixed, 1),
        op: OperatingPoint::at_vdd(0.8),
        images: Vec::new(),
        priority,
        submitted: Instant::now(),
        deadline: None,
        reply: ReplySlot::new(),
    }
}

/// Dispatcher model over the REAL `QueueState`/`pop_next`: the shipped
/// `dispatch_loop` shape — pop while non-empty, exit only when
/// shutdown AND drained, serve through the real `ReplySlot`.
fn dispatcher_drains(
    state: &Arc<(Mutex<QueueState>, Condvar)>,
    drain_before_exit: bool,
) {
    let mut seq = 0u64;
    loop {
        let req = {
            let mut st = state.0.lock().unwrap();
            loop {
                if !drain_before_exit && st.shutdown {
                    // BROKEN: exit on the flag alone, stranding
                    // whatever was admitted before the flag flipped
                    return;
                }
                if !st.queue.is_empty() {
                    break pop_next(&mut st, 2)
                        .expect("invariant: non-empty queue pops");
                }
                if st.shutdown {
                    return;
                }
                st = state.1.wait(st).unwrap();
            }
        };
        seq += 1;
        req.reply.fill(Ok(completed(seq)));
    }
}

/// Submit racing shutdown must end in exactly one of: a served result,
/// or a typed shutdown rejection. Never a hang — the explorer turns a
/// stranded waiter into a reported deadlock.
#[test]
fn shutdown_vs_submit_never_strands_a_ticket() {
    let report = explore(opts(30_000), || {
        let state =
            Arc::new((Mutex::new(QueueState::new()), Condvar::new()));
        let disp_state = state.clone();
        let dispatcher = spawn(move || dispatcher_drains(&disp_state, true));
        let shut_state = state.clone();
        let shutter = spawn(move || {
            shut_state.0.lock().unwrap().shutdown = true;
            shut_state.1.notify_all();
        });
        // submitter (the model main thread): the shipped submit shape
        let ticket = {
            let mut st = state.0.lock().unwrap();
            if st.shutdown {
                None // typed ShuttingDown rejection
            } else {
                let req = model_request(st.next_id, Priority::Normal);
                st.next_id += 1;
                let slot = req.reply.clone();
                st.queue.push(req);
                drop(st);
                state.1.notify_all();
                Some(Ticket::for_model(0, slot))
            }
        };
        if let Some(t) = ticket {
            // admitted: the ticket MUST resolve even though shutdown
            // raced the submission
            t.wait().expect("admitted request must be served");
        }
        shutter.join();
        dispatcher.join();
    });
    assert!(report.schedules > 10, "trivial exploration: {report:?}");
}

/// Counter-model: a dispatcher that exits on the shutdown flag without
/// draining strands the racing submitter's ticket — the explorer must
/// find that schedule and report the stranded waiter as a deadlock.
#[test]
fn non_draining_shutdown_is_caught() {
    let err = explore_collect(opts(30_000), || {
        let state =
            Arc::new((Mutex::new(QueueState::new()), Condvar::new()));
        let disp_state = state.clone();
        let dispatcher =
            spawn(move || dispatcher_drains(&disp_state, false));
        let shut_state = state.clone();
        let shutter = spawn(move || {
            shut_state.0.lock().unwrap().shutdown = true;
            shut_state.1.notify_all();
        });
        let ticket = {
            let mut st = state.0.lock().unwrap();
            if st.shutdown {
                None
            } else {
                let req = model_request(st.next_id, Priority::Normal);
                st.next_id += 1;
                let slot = req.reply.clone();
                st.queue.push(req);
                drop(st);
                state.1.notify_all();
                Some(Ticket::for_model(0, slot))
            }
        };
        if let Some(t) = ticket {
            t.wait().expect("admitted request must be served");
        }
        shutter.join();
        dispatcher.join();
    })
    .expect_err("explorer must catch the stranded ticket");
    assert!(err.contains("deadlock"), "unexpected failure: {err}");
}

// ---------------------------------------------------------------------
// Pop order (real pop_next: priority order within the starvation bound)
// ---------------------------------------------------------------------

/// `Priority::rank` mirrored for the spec check (the crate keeps the
/// real one `pub(crate)`).
fn rank(p: Priority) -> u8 {
    match p {
        Priority::High => 0,
        Priority::Normal => 1,
        Priority::Low => 2,
    }
}

/// Concurrent submitters + a popping dispatcher over the real
/// `pop_next`: in every explored schedule, every pop is either the
/// (priority, deadline, arrival) minimum of the queue at that moment,
/// or — exactly at the starvation bound — the globally oldest request.
#[test]
fn pop_order_spec_holds_under_concurrent_submission() {
    const BOUND: usize = 2;
    let report = explore(opts(30_000), || {
        let state =
            Arc::new((Mutex::new(QueueState::new()), Condvar::new()));
        let mut submitters = Vec::new();
        for prios in [
            [Priority::High, Priority::Low],
            [Priority::Normal, Priority::High],
        ] {
            let s = state.clone();
            submitters.push(spawn(move || {
                for p in prios {
                    let mut st = s.0.lock().unwrap();
                    let req = model_request(st.next_id, p);
                    st.next_id += 1;
                    st.queue.push(req);
                    drop(st);
                    s.1.notify_all();
                }
            }));
        }
        // dispatcher (model main thread): pop all four, checking each
        // pop against the spec computed from the queue AT THAT MOMENT
        let mut served = 0;
        while served < 4 {
            let mut st = state.0.lock().unwrap();
            if st.queue.is_empty() {
                let _ = state.1.wait(st).unwrap();
                continue;
            }
            let aged = st.priority_pops + 1 >= BOUND;
            let oldest = st
                .queue
                .iter()
                .map(|r| r.id)
                .min()
                .expect("invariant: non-empty queue has an oldest");
            let best = st
                .queue
                .iter()
                .map(|r| (rank(r.priority), r.id))
                .min()
                .expect("invariant: non-empty queue has a minimum");
            let popped = pop_next(&mut st, BOUND)
                .expect("invariant: non-empty queue pops");
            if aged {
                assert_eq!(
                    popped.id, oldest,
                    "aged pop must take the globally oldest"
                );
            } else {
                assert_eq!(
                    (rank(popped.priority), popped.id),
                    best,
                    "ordered pop must take the (priority, arrival) min"
                );
            }
            served += 1;
        }
        for s in submitters {
            s.join();
        }
    });
    assert!(report.schedules > 10, "trivial exploration: {report:?}");
}

// ---------------------------------------------------------------------
// Cancellation and deadline-reap races (real cancel_queued /
// shed_expired / release_inflight under the explorer)
// ---------------------------------------------------------------------

/// `Ticket::cancel` racing the dispatcher's pop, over the REAL
/// `cancel_queued`: in every explored schedule exactly one side fills
/// the reply slot (the canceller only when the request was still
/// queued), the waiter resolves, and the inflight slot releases
/// exactly once.
#[test]
fn cancel_vs_pop_resolves_exactly_once() {
    let report = explore(opts(30_000), || {
        let state = Arc::new(Mutex::new(QueueState::new()));
        let fills = Arc::new(AtomicUsize::new(0));
        let slot = {
            let mut st = state.lock().unwrap();
            let req = model_request(0, Priority::Normal);
            let slot = req.reply.clone();
            *st.inflight.entry("t".into()).or_insert(0) += 1;
            st.queue.push(req);
            slot
        };
        // dispatcher: pop if still queued, release inflight under the
        // lock, fill Ok outside it (the shipped serve shape)
        let disp_state = state.clone();
        let disp_fills = fills.clone();
        let dispatcher = spawn(move || {
            let popped = {
                let mut st = disp_state.lock().unwrap();
                let popped = pop_next(&mut st, 2);
                if let Some(req) = &popped {
                    release_inflight(&mut st, &req.tenant);
                }
                popped
            };
            if let Some(req) = popped {
                disp_fills.fetch_add(1, Ordering::SeqCst);
                req.reply.fill(Ok(completed(1)));
            }
        });
        // canceller: the shipped cancel_request shape — fill ONLY when
        // cancel_queued actually removed the request
        let cxl_state = state.clone();
        let cxl_fills = fills.clone();
        let canceller = spawn(move || {
            let cancelled = {
                let mut st = cxl_state.lock().unwrap();
                cancel_queued(&mut st, 0)
            };
            if let Some(req) = cancelled {
                cxl_fills.fetch_add(1, Ordering::SeqCst);
                req.reply
                    .fill(Err(ServeError::Cancelled { id: 0 }.into()));
            }
        });
        // waiter: either outcome of the race is legal; resolving is not
        // optional
        let _ = Ticket::for_model(0, slot).wait();
        dispatcher.join();
        canceller.join();
        assert_eq!(
            fills.load(Ordering::SeqCst),
            1,
            "invariant: exactly one terminal fill per request"
        );
        assert!(
            state.lock().unwrap().inflight.is_empty(),
            "invariant: inflight slot released exactly once"
        );
    });
    assert!(report.schedules > 10, "trivial exploration: {report:?}");
}

/// Counter-model: a cancel that fills the reply slot without checking
/// whether the dispatcher already popped the request mutates a slot it
/// no longer owns — in the schedule where the pop wins, the request
/// resolves twice. The explorer must find that schedule.
#[test]
fn cancel_after_pop_mutating_the_slot_is_caught() {
    let err = explore_collect(opts(30_000), || {
        let state = Arc::new(Mutex::new(QueueState::new()));
        let fills = Arc::new(AtomicUsize::new(0));
        let slot = {
            let mut st = state.lock().unwrap();
            let req = model_request(0, Priority::Normal);
            let slot = req.reply.clone();
            st.queue.push(req);
            slot
        };
        let disp_state = state.clone();
        let disp_fills = fills.clone();
        let dispatcher = spawn(move || {
            let popped = {
                let mut st = disp_state.lock().unwrap();
                pop_next(&mut st, 2)
            };
            if let Some(req) = popped {
                disp_fills.fetch_add(1, Ordering::SeqCst);
                req.reply.fill(Ok(completed(1)));
            }
        });
        let cxl_state = state.clone();
        let cxl_fills = fills.clone();
        let cxl_slot = slot.clone();
        let canceller = spawn(move || {
            {
                let mut st = cxl_state.lock().unwrap();
                let _ = cancel_queued(&mut st, 0);
            }
            // BROKEN: fill unconditionally — even when cancel_queued
            // returned None because the pop already won the race
            cxl_fills.fetch_add(1, Ordering::SeqCst);
            cxl_slot.fill(Err(ServeError::Cancelled { id: 0 }.into()));
        });
        let _ = Ticket::for_model(0, slot).wait();
        dispatcher.join();
        canceller.join();
        assert_eq!(
            fills.load(Ordering::SeqCst),
            1,
            "invariant: exactly one terminal fill per request"
        );
    })
    .expect_err("explorer must catch the double fill");
    assert!(
        err.contains("exactly one terminal fill"),
        "unexpected failure: {err}"
    );
}

/// The deadline reaper racing the dispatcher's pop, over the REAL
/// `shed_expired`: the expired request is resolved exactly once —
/// either shed with `DeadlineExceeded` or served (serve-anyway pop) —
/// and its inflight slot releases exactly once. The sweep time is a
/// parameter (`shed_expired` never reads the clock), keeping every
/// explored schedule control-flow deterministic.
#[test]
fn reaper_vs_completion_resolves_exactly_once() {
    let report = explore(opts(30_000), || {
        let state = Arc::new(Mutex::new(QueueState::new()));
        let fills = Arc::new(AtomicUsize::new(0));
        let (slot, reap_now) = {
            let mut st = state.lock().unwrap();
            let mut req = model_request(0, Priority::Normal);
            // expired relative to the reaper's sweep instant below
            req.deadline = Some(req.submitted);
            let reap_now = req.submitted + Duration::from_secs(1);
            let slot = req.reply.clone();
            *st.inflight.entry("t".into()).or_insert(0) += 1;
            st.queue.push(req);
            (slot, reap_now)
        };
        let disp_state = state.clone();
        let disp_fills = fills.clone();
        let dispatcher = spawn(move || {
            let popped = {
                let mut st = disp_state.lock().unwrap();
                let popped = pop_next(&mut st, 2);
                if let Some(req) = &popped {
                    release_inflight(&mut st, &req.tenant);
                }
                popped
            };
            if let Some(req) = popped {
                disp_fills.fetch_add(1, Ordering::SeqCst);
                req.reply.fill(Ok(completed(1)));
            }
        });
        let reap_state = state.clone();
        let reap_fills = fills.clone();
        let reaper = spawn(move || {
            let expired = {
                let mut st = reap_state.lock().unwrap();
                shed_expired(&mut st, reap_now)
            };
            for req in expired {
                reap_fills.fetch_add(1, Ordering::SeqCst);
                req.reply.fill(Err(ServeError::DeadlineExceeded {
                    id: req.id,
                    late_us: 0,
                }
                .into()));
            }
        });
        let _ = Ticket::for_model(0, slot).wait();
        dispatcher.join();
        reaper.join();
        assert_eq!(
            fills.load(Ordering::SeqCst),
            1,
            "invariant: exactly one terminal fill per request"
        );
        assert!(
            state.lock().unwrap().inflight.is_empty(),
            "invariant: inflight slot released exactly once"
        );
    });
    assert!(report.schedules > 10, "trivial exploration: {report:?}");
}
