//! Deploy-time autotuner acceptance (ISSUE 6): tuning changes speed,
//! never logits. A trial budget of 0 must resolve to the exact
//! heuristic configuration, tuned plans must be bitwise identical to
//! the heuristic path across the full (batch, threads, mode) matrix,
//! persisted configs must round-trip and invalidate on a stale machine
//! fingerprint, and the plan cache must account the tuned config's
//! bytes when the tuned plan replaces the heuristic resident.

#![cfg(feature = "native")]

use marsellus::coordinator::{Coordinator, Schedule, ScheduleMode};
use marsellus::dnn::{NetworkSpec, PrecisionConfig};
use marsellus::power::OperatingPoint;
use marsellus::rbe::functional::PlaneWidth;
use marsellus::runtime::{
    machine_fingerprint, LayerPlan, Runtime, SplitFactors, TuneOptions,
    TunedConfig, HYBRID_TILE_SPEEDUP_CAP, MAX_HYBRID_CUTOVER,
};
use marsellus::util::Rng;

fn coordinator() -> Coordinator {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    let rt = Runtime::native(&dir).expect("native runtime");
    Coordinator::with_runtime(rt).expect("coordinator")
}

fn op() -> OperatingPoint {
    OperatingPoint::at_vdd(0.8)
}

const MODES: [ScheduleMode; 4] = [
    ScheduleMode::Auto,
    ScheduleMode::Batch,
    ScheduleMode::Latency,
    ScheduleMode::Hybrid,
];

/// A trial budget of 0 is the A/B control: no measurement happens and
/// the deployment serves the exact configuration the fixed heuristics
/// would pick — same widths, unit split factors, fixed hybrid cutover —
/// with logits bitwise equal to the plain deploy.
#[test]
fn trial_budget_zero_is_the_exact_heuristic_config() {
    let coord = coordinator();
    let spec = NetworkSpec::new("kws", PrecisionConfig::Mixed, 7);
    let heuristic = coord.deploy(&spec).unwrap();
    let hplan = coord.plan_for(&spec).unwrap();
    let want_widths: Vec<Option<PlaneWidth>> = hplan
        .steps()
        .iter()
        .filter_map(|s| match &s.plan {
            LayerPlan::Conv(c) => Some(c.plane_width()),
            _ => None,
        })
        .collect();

    let d = coord
        .deploy_tuned(&spec, &TuneOptions::new(4, 0))
        .unwrap();
    let cfg = d.tuned().expect("trials-0 deploy still carries a config");
    assert_eq!(cfg.trials, 0, "control config must record 0 trials");
    assert_eq!(cfg.tile_speedup, 0.0, "control config is unmeasured");
    assert_eq!(
        d.hybrid_cutover(),
        HYBRID_TILE_SPEEDUP_CAP,
        "unmeasured config must fall back to the fixed cutover cap"
    );
    assert_eq!(
        cfg.layers.len(),
        want_widths.len(),
        "one pick per conv layer"
    );
    for (pick, want) in cfg.layers.iter().zip(&want_widths) {
        assert_eq!(
            pick.factors,
            SplitFactors::UNIT,
            "{}: control pick must keep unit split factors",
            pick.layer
        );
        assert_eq!(
            pick.width, *want,
            "{}: control pick must keep the heuristic width",
            pick.layer
        );
        assert_eq!(pick.speedup(), 1.0, "{}: unmeasured", pick.layer);
    }

    // and the control plan is bitwise identical to the plain deploy
    let mut rng = Rng::new(60);
    let images: Vec<Vec<i32>> =
        (0..3).map(|_| heuristic.random_input(&mut rng)).collect();
    let want: Vec<Vec<i32>> = heuristic
        .infer_batch_opts(&op(), &images, 1, false)
        .unwrap()
        .into_iter()
        .map(|r| r.logits)
        .collect();
    for threads in [1usize, 4] {
        let got: Vec<Vec<i32>> = d
            .infer_scheduled(&op(), &images, Schedule::hybrid(threads))
            .unwrap()
            .into_iter()
            .map(|r| r.logits)
            .collect();
        assert_eq!(got, want, "control plan diverged at {threads} threads");
    }
}

/// Measured tuning on the signed-head KWS net: every (batch, threads,
/// mode) combination of the tuned deployment equals the heuristic
/// deployment's sequential per-call path, and the measured config is
/// well-formed (positive tile speedup, cutover within bounds).
#[test]
fn tuned_logits_match_heuristic_across_schedule_matrix() {
    let coord = coordinator();
    let spec = NetworkSpec::new("kws", PrecisionConfig::Mixed, 7);
    // heuristic deployment FIRST: its Arc keeps the replaced resident
    // alive after deploy_tuned swaps the cache entry
    let heuristic = coord.deploy(&spec).unwrap();
    let d = coord
        .deploy_tuned(&spec, &TuneOptions::new(4, 2))
        .unwrap();
    let cfg = d.tuned().expect("tuned config").clone();
    assert!(cfg.trials > 0);
    assert!(
        cfg.tile_speedup > 0.0,
        "measured config must record the pooled speedup"
    );
    let cutover = d.hybrid_cutover();
    assert!(
        (1..=MAX_HYBRID_CUTOVER).contains(&cutover),
        "cutover {cutover} out of bounds"
    );

    let mut rng = Rng::new(61);
    for batch in [1usize, 3, 8, 17] {
        let images: Vec<Vec<i32>> =
            (0..batch).map(|_| heuristic.random_input(&mut rng)).collect();
        // sequential per-call reference from the HEURISTIC deployment
        let want: Vec<Vec<i32>> = heuristic
            .infer_batch_opts(&op(), &images, 1, false)
            .unwrap()
            .into_iter()
            .map(|r| r.logits)
            .collect();
        for threads in [1usize, 4, 16] {
            for mode in MODES {
                let got: Vec<Vec<i32>> = d
                    .infer_scheduled(
                        &op(),
                        &images,
                        Schedule { threads, mode },
                    )
                    .unwrap()
                    .into_iter()
                    .map(|r| r.logits)
                    .collect();
                assert_eq!(
                    got, want,
                    "tuned kws batch {batch}, {threads} threads, \
                     {mode:?} diverged from the heuristic per-call path"
                );
            }
        }
    }

    // lighter pass on the wide-word ResNet-20 plan path
    let spec = NetworkSpec::new("resnet20", PrecisionConfig::Mixed, 42);
    let heuristic = coord.deploy(&spec).unwrap();
    let d = coord
        .deploy_tuned(&spec, &TuneOptions::new(4, 1))
        .unwrap();
    let images: Vec<Vec<i32>> =
        (0..5).map(|_| heuristic.random_input(&mut rng)).collect();
    let want: Vec<Vec<i32>> = images
        .iter()
        .map(|img| heuristic.infer(&op(), img).unwrap().logits)
        .collect();
    for mode in [ScheduleMode::Hybrid, ScheduleMode::Auto] {
        let got: Vec<Vec<i32>> = d
            .infer_scheduled(&op(), &images, Schedule { threads: 4, mode })
            .unwrap()
            .into_iter()
            .map(|r| r.logits)
            .collect();
        assert_eq!(got, want, "tuned resnet20 {mode:?}");
    }
}

/// Persistence: a tuned deploy writes the config beside the plan cache,
/// the file round-trips byte-for-byte, a stale machine fingerprint in
/// the content invalidates it, and a fresh deploy re-tunes (and
/// re-persists) for the current machine.
#[test]
fn persisted_config_round_trips_and_stale_fingerprint_invalidates() {
    let dir = std::env::temp_dir()
        .join(format!("marsellus-autotune-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = NetworkSpec::new("kws", PrecisionConfig::Mixed, 7);
    let opts = TuneOptions {
        threads: 4,
        trials: 1,
        persist_dir: Some(dir.clone()),
    };
    let fp = machine_fingerprint();

    let coord = coordinator();
    let d = coord.deploy_tuned(&spec, &opts).unwrap();
    let cfg = d.tuned().expect("tuned config").clone();
    assert_eq!(cfg.fingerprint, fp);

    // byte-for-byte round trip through the persisted TSV (string-level:
    // the in-memory config carries full-precision timings, the TSV is
    // the canonical rounded form and must reproduce itself exactly)
    let loaded = TunedConfig::load(&dir, &cfg.spec, &fp)
        .unwrap()
        .expect("config was persisted");
    assert_eq!(loaded.to_tsv(), cfg.to_tsv(), "round trip drifted");
    assert_eq!(loaded.layers.len(), cfg.layers.len());
    assert_eq!(loaded.threads, cfg.threads);
    assert_eq!(loaded.trials, cfg.trials);

    // doctor the persisted content to a foreign machine fingerprint:
    // the stale config must be ignored, not served
    let path = TunedConfig::path_in(&dir, &cfg.spec, &fp);
    let text = std::fs::read_to_string(&path).unwrap();
    let stale = text.replace(&fp, "v1-nowhere-fake-999c");
    assert_ne!(stale, text, "fingerprint must appear in the content");
    std::fs::write(&path, stale).unwrap();
    assert!(
        TunedConfig::load(&dir, &cfg.spec, &fp).unwrap().is_none(),
        "stale fingerprint must invalidate the persisted config"
    );

    // a fresh coordinator (empty plan cache) re-tunes for this machine
    // and re-persists over the stale file
    let coord2 = coordinator();
    let d2 = coord2.deploy_tuned(&spec, &opts).unwrap();
    assert_eq!(d2.tuned().unwrap().fingerprint, fp);
    let refreshed = TunedConfig::load(&dir, &cfg.spec, &fp)
        .unwrap()
        .expect("re-tuned config was re-persisted");
    assert!(refreshed.trials > 0);
    assert_eq!(refreshed.fingerprint, fp);

    let _ = std::fs::remove_dir_all(&dir);
}

/// Plan-cache accounting: a tuned deploy on a spec with a heuristic
/// resident replaces it (one build, no eviction), the replacement's
/// byte accounting includes the tuned config (`TunedConfig::bytes`),
/// a second tuned deploy hits the cache, and the replaced heuristic
/// deployment keeps serving from its own handle.
#[test]
fn tuned_plan_replaces_resident_and_accounts_config_bytes() {
    let coord = coordinator();
    let spec = NetworkSpec::new("kws", PrecisionConfig::Mixed, 7);
    let heuristic = coord.deploy(&spec).unwrap();
    let hplan = coord.plan_for(&spec).unwrap();
    let rt = &coord.runtime;
    assert_eq!(rt.plan_bytes(), hplan.bytes());

    // trials = 0 keeps the exact heuristic widths, so the replacement's
    // size is exactly the heuristic plan plus the attached config
    let opts = TuneOptions::new(2, 0);
    let builds = rt.plan_builds();
    let evictions = rt.plan_evictions();
    let d = coord.deploy_tuned(&spec, &opts).unwrap();
    let cfg = d.tuned().expect("tuned config").clone();
    assert_eq!(
        rt.plan_builds(),
        builds + 1,
        "replacing the heuristic resident counts as a build"
    );
    assert_eq!(
        rt.plan_evictions(),
        evictions,
        "a replacement is not an eviction"
    );
    assert_eq!(
        rt.plan_bytes(),
        hplan.bytes() + cfg.bytes(),
        "cache accounting must include the tuned config bytes"
    );

    // second tuned deploy with the same options is a cache hit
    let builds = rt.plan_builds();
    let hits = rt.plan_hits();
    let d2 = coord.deploy_tuned(&spec, &opts).unwrap();
    assert_eq!(rt.plan_builds(), builds, "second tuned deploy rebuilt");
    assert!(rt.plan_hits() > hits, "second tuned deploy missed");
    assert!(d2.tuned().is_some());

    // the replaced heuristic deployment still serves (its Arc survives)
    // and stays bitwise equal to the tuned one
    let mut rng = Rng::new(62);
    let image = heuristic.random_input(&mut rng);
    let a = heuristic.infer(&op(), &image).unwrap();
    let b = d.infer(&op(), &image).unwrap();
    assert_eq!(a.logits, b.logits, "replacement changed logits");
}
