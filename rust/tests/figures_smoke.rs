//! Smoke test: every figure/table generator renders (fast mode).

#[test]
fn all_figures_render_fast() {
    for id in marsellus::figures::ALL {
        let out = marsellus::figures::generate(id, true)
            .unwrap_or_else(|e| panic!("{id}: {e}"));
        assert!(out.len() > 80, "{id} output too small:\n{out}");
        assert!(out.lines().count() >= 4, "{id}");
    }
}

#[test]
fn unknown_figure_rejected() {
    assert!(marsellus::figures::generate("fig99", true).is_err());
}
