//! Native backend layer tests: parity between the backend dispatch path
//! and direct `rbe::functional` bit-serial calls on a small
//! Conv3x3 → Conv1x1 → Linear tower, runtime cache-hit behaviour, and
//! cross-thread sharing of one runtime.

#![cfg(feature = "native")]

use std::sync::Arc;

use marsellus::dnn::Manifest;
use marsellus::rbe::functional::{conv_bitserial, trim_input, NormQuant};
use marsellus::rbe::RbeJob;
use marsellus::runtime::{NativeBackend, NativeNumerics, Runtime, TensorArg};
use marsellus::util::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Runtime {
    Runtime::native(&artifacts_dir()).expect("native runtime")
}

struct TowerLayer {
    name: &'static str,
    /// (mode3x3, h, cin, cout, stride, w_bits, i_bits, o_bits, shift)
    sig: (bool, usize, usize, usize, usize, usize, usize, usize, u32),
}

/// A small tower drawn from the built-in zoo: quickstart conv3x3, the
/// uniform8 stage3 downsample conv1x1, and the fc linear layer.
fn tower() -> Vec<TowerLayer> {
    vec![
        TowerLayer {
            name: "conv3x3_h16_ci32_co32_s1_w4i4o4",
            sig: (true, 16, 32, 32, 1, 4, 4, 4, 10),
        },
        TowerLayer {
            // shift_for(32, 8, 8, 8, 1) = round(2.5 + 8.42) = 11
            name: "conv1x1_h16_ci32_co64_s2_w8i8o8",
            sig: (false, 16, 32, 64, 2, 8, 8, 8, 11),
        },
    ]
}

/// Native backend output == direct bit-serial datapath call, for each
/// conv layer of the tower. (The backend's Auto numerics picks the
/// oracle for large jobs; both are property-tested bit-identical, and
/// this test closes the loop at the backend-dispatch level.)
#[test]
fn tower_parity_native_vs_bitserial() {
    let rt = runtime();
    let zoo = Manifest::builtin();
    let mut rng = Rng::new(0xB17);
    for l in tower() {
        let (is3x3, h, cin, cout, stride, wb, ib, ob, _) = l.sig;
        if !rt.has_artifact(l.name) {
            panic!("builtin zoo lost {}", l.name);
        }
        // shift comes from the zoo (manifest is the contract)
        let shift = zoo.get(l.name).unwrap().shift;
        assert_eq!(shift, l.sig.8, "{}: zoo shift drifted", l.name);

        let (full, taps) = if is3x3 { (h + 2, 3) } else { (h, 1) };
        let x: Vec<i32> = (0..full * full * cin)
            .map(|_| rng.range_i32(0, 1 << ib))
            .collect();
        let whalf = 1 << (wb - 1);
        let w: Vec<i32> = (0..cout * cin * taps * taps)
            .map(|_| rng.range_i32(-whalf, whalf))
            .collect();
        let scale: Vec<i32> = (0..cout).map(|_| rng.range_i32(1, 16)).collect();
        let bias: Vec<i32> =
            (0..cout).map(|_| rng.range_i32(-500, 500)).collect();

        let w_dims = if is3x3 {
            vec![cout, cin, 3, 3]
        } else {
            vec![cout, cin]
        };
        let exe = rt.load(l.name).unwrap();
        let got = exe
            .execute_i32(&[
                TensorArg::new(x.clone(), vec![full, full, cin]),
                TensorArg::new(w.clone(), w_dims),
                TensorArg::scalar_vec(scale.clone()),
                TensorArg::scalar_vec(bias.clone()),
            ])
            .unwrap();

        let h_out = (full - taps) / stride + 1;
        let job = if is3x3 {
            RbeJob::conv3x3(h_out, h_out, cin, cout, stride, wb, ib, ob)
        } else {
            RbeJob::conv1x1(h_out, h_out, cin, cout, stride, wb, ib, ob)
        }
        .unwrap();
        let xt = trim_input(&x, full, job.h_in(), cin);
        let nq = NormQuant::new(scale, bias, shift);
        let want = conv_bitserial(&job, &xt, &w, &nq).unwrap();
        assert_eq!(got[0], want, "{}", l.name);
    }
}

/// Linear layer parity: backend fc output == bit-serial 1×1 job.
#[test]
fn linear_parity_native_vs_bitserial() {
    let rt = runtime();
    let name = "linear_ci64_co10_w8i8o8";
    let shift = Manifest::builtin().get(name).unwrap().shift;
    let mut rng = Rng::new(0xFC);
    let x: Vec<i32> = (0..64).map(|_| rng.range_i32(0, 256)).collect();
    let w: Vec<i32> = (0..10 * 64).map(|_| rng.range_i32(-128, 128)).collect();
    let scale: Vec<i32> = (0..10).map(|_| rng.range_i32(1, 16)).collect();
    let bias: Vec<i32> = (0..10).map(|_| rng.range_i32(-500, 500)).collect();
    let got = rt
        .load(name)
        .unwrap()
        .execute_i32(&[
            TensorArg::new(x.clone(), vec![64]),
            TensorArg::new(w.clone(), vec![10, 64]),
            TensorArg::scalar_vec(scale.clone()),
            TensorArg::scalar_vec(bias.clone()),
        ])
        .unwrap();
    let job = RbeJob::conv1x1(1, 1, 64, 10, 1, 8, 8, 8).unwrap();
    let nq = NormQuant::new(scale, bias, shift);
    assert_eq!(got[0], conv_bitserial(&job, &x, &w, &nq).unwrap());
}

/// Explicit-numerics backends agree with each other through the full
/// backend dispatch path (not just the kernel property tests).
#[test]
fn bitserial_and_reference_numerics_agree_via_backend() {
    let dir = artifacts_dir();
    let name = "conv3x3_h16_ci32_co32_s1_w4i4o4";
    let mk = |n: NativeNumerics| {
        Runtime::with_backend(
            Arc::new(NativeBackend::new().with_numerics(n)),
            &dir,
        )
    };
    let a = mk(NativeNumerics::BitSerial);
    let b = mk(NativeNumerics::Reference);
    let mut rng = Rng::new(5);
    let hp = 18;
    let args = vec![
        TensorArg::new(
            (0..hp * hp * 32).map(|_| rng.range_i32(0, 16)).collect(),
            vec![hp, hp, 32],
        ),
        TensorArg::new(
            (0..32 * 32 * 9).map(|_| rng.range_i32(-8, 8)).collect(),
            vec![32, 32, 3, 3],
        ),
        TensorArg::scalar_vec((0..32).map(|_| rng.range_i32(1, 16)).collect()),
        TensorArg::scalar_vec((0..32).map(|_| rng.range_i32(-500, 500)).collect()),
    ];
    let ra = a.load(name).unwrap().execute_i32(&args).unwrap();
    let rb = b.load(name).unwrap().execute_i32(&args).unwrap();
    assert_eq!(ra, rb);
}

/// The compile cache: one compilation per artifact, `Arc`-shared after.
#[test]
fn runtime_cache_hits() {
    let rt = runtime();
    assert_eq!((rt.cache_hits(), rt.cache_misses()), (0, 0));
    let a = rt.load("avgpool_h8_k64").unwrap();
    assert_eq!((rt.cache_hits(), rt.cache_misses()), (0, 1));
    let b = rt.load("avgpool_h8_k64").unwrap();
    assert_eq!((rt.cache_hits(), rt.cache_misses()), (1, 1));
    assert!(Arc::ptr_eq(&a, &b), "cache must share the same executable");
    let _c = rt.load("linear_ci64_co10_w8i8o8").unwrap();
    assert_eq!((rt.cache_hits(), rt.cache_misses()), (1, 2));
    assert_eq!(rt.cached_executables(), 2);
}

/// One runtime shared by many threads: concurrent loads of the same
/// artifact compile at most a handful of times (benign race), results
/// are identical, and the cache converges to one entry.
#[test]
fn runtime_is_shared_across_threads() {
    let rt = runtime();
    let x = TensorArg::new(vec![1i32; 8 * 8 * 64], vec![8, 8, 64]);
    let outputs: Vec<Vec<i32>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let rt = &rt;
                let x = x.clone();
                s.spawn(move || {
                    let exe = rt.load("avgpool_h8_k64").unwrap();
                    exe.execute_i32(&[x]).unwrap().remove(0)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for o in &outputs {
        assert_eq!(o, &outputs[0]);
    }
    assert_eq!(rt.cached_executables(), 1);
    assert!(rt.cache_hits() + rt.cache_misses() >= 8);
}
