//! Integration tests over the execution runtime: the three-way
//! equivalence (backend output == Rust bit-serial datapath == plain
//! integer oracle) and manifest/zoo consistency.
//!
//! The default native backend needs nothing on disk, so these run
//! everywhere; anything that *does* require `make artifacts` output
//! skips cleanly via `Runtime::has_artifact` / manifest presence checks
//! instead of erroring.

#![cfg(feature = "native")]

use marsellus::dnn::{Manifest, PrecisionConfig};
use marsellus::rbe::functional::{conv_bitserial, conv_reference, NormQuant};
use marsellus::rbe::{RbeJob, RbeMode};
use marsellus::runtime::{BackendKind, Runtime, TensorArg};
use marsellus::util::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Runtime {
    Runtime::native(&artifacts_dir()).expect("native runtime")
}

#[test]
fn default_backend_is_native() {
    // `cpu()` is the historical entry point every caller used; with no
    // MARSELLUS_BACKEND=pjrt it must resolve to the native backend.
    if std::env::var("MARSELLUS_BACKEND").as_deref() == Ok("pjrt") {
        eprintln!("SKIP: MARSELLUS_BACKEND=pjrt set in the environment");
        return;
    }
    let rt = Runtime::cpu(artifacts_dir().to_str().unwrap()).unwrap();
    assert_eq!(rt.kind(), BackendKind::Native);
    assert_eq!(rt.platform(), "native");
}

#[test]
fn manifest_covers_both_network_configs() {
    // The merged (builtin + optional disk) manifest must validate both
    // network configs whether or not `make artifacts` has run.
    let m = Manifest::load_or_builtin(&artifacts_dir()).unwrap();
    m.validate_network(PrecisionConfig::Uniform8).unwrap();
    m.validate_network(PrecisionConfig::Mixed).unwrap();
}

#[test]
fn every_artifact_compiles() {
    let rt = runtime();
    let names = rt.list_artifacts();
    assert!(names.len() >= 20, "{}", names.len());
    for n in &names {
        if n == "model" {
            continue; // makefile sentinel, not a real module
        }
        rt.load(n).unwrap_or_else(|e| panic!("artifact {n}: {e}"));
    }
    assert_eq!(rt.cache_misses() as usize, rt.cached_executables());
}

/// Three-way equivalence on the quickstart conv: backend output ==
/// Rust bit-serial datapath == plain integer oracle, over random inputs.
#[test]
fn three_way_equivalence_quickstart() {
    let rt = runtime();
    let (h, cin, cout, bits, shift) = (16usize, 32usize, 32usize, 4usize, 10);
    let name =
        format!("conv3x3_h{h}_ci{cin}_co{cout}_s1_w{bits}i{bits}o{bits}");
    if !rt.has_artifact(&name) {
        eprintln!("SKIP: backend cannot execute {name}");
        return;
    }
    let exe = rt.load(&name).unwrap();
    let job = RbeJob::conv3x3(h, h, cin, cout, 1, bits, bits, bits).unwrap();
    let mut rng = Rng::new(0xDEAD);
    for trial in 0..3 {
        let hp = h + 2;
        let x: Vec<i32> =
            (0..hp * hp * cin).map(|_| rng.range_i32(0, 16)).collect();
        let w: Vec<i32> =
            (0..cout * cin * 9).map(|_| rng.range_i32(-8, 8)).collect();
        let scale: Vec<i32> =
            (0..cout).map(|_| rng.range_i32(1, 16)).collect();
        let bias: Vec<i32> =
            (0..cout).map(|_| rng.range_i32(-500, 500)).collect();
        let art = exe
            .execute_i32(&[
                TensorArg::new(x.clone(), vec![hp, hp, cin]),
                TensorArg::new(w.clone(), vec![cout, cin, 3, 3]),
                TensorArg::scalar_vec(scale.clone()),
                TensorArg::scalar_vec(bias.clone()),
            ])
            .unwrap();
        let nq = NormQuant::new(scale, bias, shift as u32);
        let bit = conv_bitserial(&job, &x, &w, &nq).unwrap();
        let oracle = conv_reference(&job, &x, &w, &nq).unwrap();
        assert_eq!(bit, oracle, "trial {trial}: bit-serial vs oracle");
        assert_eq!(art[0], bit, "trial {trial}: backend vs bit-serial");
    }
}

/// The 1x1 downsample agrees with the datapath model, including the
/// strided access pattern.
#[test]
fn strided_conv1x1_matches_datapath() {
    let rt = runtime();
    // mixed-config stage2 downsample: h32 ci16 co32 s2 w8 i4 o4
    let name = "conv1x1_h32_ci16_co32_s2_w8i4o4";
    if !rt.has_artifact(name) {
        eprintln!("SKIP: backend cannot execute {name}");
        return;
    }
    let exe = rt.load(name).unwrap();
    let m = Manifest::load_or_builtin(&artifacts_dir()).unwrap();
    let e = m.get(name).expect("manifest entry");
    let job = RbeJob {
        mode: RbeMode::Conv1x1,
        h_out: e.h.div_ceil(e.stride),
        w_out: e.h.div_ceil(e.stride),
        k_in: e.cin,
        k_out: e.cout,
        stride: e.stride,
        w_bits: e.w_bits,
        i_bits: e.i_bits,
        o_bits: e.o_bits,
    };
    let mut rng = Rng::new(77);
    let x: Vec<i32> = (0..e.h * e.h * e.cin)
        .map(|_| rng.range_i32(0, 1 << e.i_bits))
        .collect();
    let w: Vec<i32> = (0..e.cout * e.cin)
        .map(|_| rng.range_i32(-(1 << (e.w_bits - 1)), 1 << (e.w_bits - 1)))
        .collect();
    let scale: Vec<i32> = (0..e.cout).map(|_| rng.range_i32(1, 8)).collect();
    let bias: Vec<i32> =
        (0..e.cout).map(|_| rng.range_i32(-100, 100)).collect();
    let art = exe
        .execute_i32(&[
            TensorArg::new(x.clone(), vec![e.h, e.h, e.cin]),
            TensorArg::new(w.clone(), vec![e.cout, e.cin]),
            TensorArg::scalar_vec(scale.clone()),
            TensorArg::scalar_vec(bias.clone()),
        ])
        .unwrap();
    // NOTE: the artifact gathers x[::2, ::2] of the *full* input, i.e.
    // h_out = ceil(h/2); the functional model must match.
    let nq = NormQuant::new(scale, bias, e.shift);
    // the job expects the strided input extent: (h_out-1)*stride + 1 rows
    let need = (job.h_out - 1) * job.stride + 1;
    let mut xs = Vec::with_capacity(need * need * e.cin);
    for r in 0..need {
        xs.extend_from_slice(&x[r * e.h * e.cin..(r * e.h + need) * e.cin]);
    }
    let bit = conv_bitserial(&job, &xs, &w, &nq).unwrap();
    assert_eq!(art[0], bit);
}

/// Malformed invocations fail loudly rather than corrupting memory.
#[test]
fn wrong_shape_is_an_error() {
    let rt = runtime();
    let exe = rt.load("avgpool_h8_k64").unwrap();
    let bad = exe.execute_i32(&[TensorArg::new(vec![0; 10], vec![10])]);
    assert!(bad.is_err());
}

#[test]
fn missing_artifact_is_an_error() {
    let rt = runtime();
    assert!(!rt.has_artifact("no_such_artifact"));
    assert!(rt.load("no_such_artifact").is_err());
}

/// The PJRT loader itself: only exercised when artifact *files* exist on
/// disk (and, with the vendored xla stub, client construction may fail —
/// that must surface as a clean error, not a crash).
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_backend_errors_are_clean() {
    let dir = artifacts_dir();
    match Runtime::pjrt(&dir) {
        Ok(rt) => {
            // real xla crate patched in: artifacts must load if present
            let name = "avgpool_h8_k64";
            if !rt.has_artifact(name) {
                eprintln!("SKIP: {name}.hlo.txt missing; run `make artifacts`");
                return;
            }
            rt.load(name).unwrap();
        }
        Err(e) => {
            assert!(e.to_string().contains("pjrt"), "unexpected error: {e}");
        }
    }
}
