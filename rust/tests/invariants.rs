//! Property-style invariant tests over the coordinator-side models
//! (no artifacts needed): tiler coverage, scheduler physics, RBE
//! functional equivalence under randomized jobs, cluster fault handling,
//! and ISA round-trips. A seeded in-tree PRNG drives the case sweep
//! (proptest is not vendored in this environment).

use marsellus::cluster::{Cluster, ClusterConfig, TCDM_BASE};
use marsellus::dnn::{resnet18_layers, resnet20_layers, Layer, LayerOp,
                     PrecisionConfig};
use marsellus::isa::{disasm, AluOp, Instr, IsaLevel, Prec, ProgramBuilder};
use marsellus::kernels::matmul::{matmul_reference, random_operands,
                                 MatmulKernel, MatmulProblem};
use marsellus::mapping::{Scheduler, Tiler};
use marsellus::power::{fmax_mhz, OperatingPoint, PowerModel, Workload};
use marsellus::rbe::functional::{conv_bitserial, conv_reference, NormQuant};
use marsellus::rbe::{RbeJob, RbeMode, RbeTiming};
use marsellus::util::Rng;

/// Tiler invariant: for random budgets, tiles exactly cover the layer and
/// never exceed the budget (or the tiler errors out loudly).
#[test]
fn tiler_coverage_under_random_budgets() {
    let mut rng = Rng::new(1);
    let layers: Vec<Layer> = resnet20_layers(PrecisionConfig::Uniform8)
        .into_iter()
        .chain(resnet20_layers(PrecisionConfig::Mixed))
        .chain(resnet18_layers())
        .filter(|l| matches!(l.op, LayerOp::Conv3x3 | LayerOp::Conv1x1))
        .collect();
    let mut ok = 0;
    for _ in 0..200 {
        let l = &layers[rng.index(layers.len())];
        let budget = 8 * 1024 + rng.index(120 * 1024) as u64;
        let t = Tiler { l1_budget: budget };
        match t.tile(l) {
            Ok(tiling) => {
                ok += 1;
                assert!(tiling.l1_bytes <= budget, "{}: budget", l.name);
                let covered: usize =
                    tiling.tiles.iter().map(|t| t.rows * t.kout).sum();
                assert_eq!(covered, l.h_out() * l.cout, "{}", l.name);
                // weights loaded exactly once per kout slice
                let loads =
                    tiling.tiles.iter().filter(|t| t.loads_weights).count();
                assert_eq!(loads, l.cout.div_ceil(tiling.kout_per_tile));
            }
            Err(_) => {} // too small: allowed, as long as it's an error
        }
    }
    assert!(ok > 50, "only {ok}/200 budgets tiled — sweep degenerate");
}

/// Scheduler invariant: per-layer latency is exactly the max of the three
/// overlapped components, and energy is positive and finite.
#[test]
fn scheduler_latency_is_component_max() {
    let s = Scheduler::default();
    let mut rng = Rng::new(2);
    for _ in 0..20 {
        let vdd = 0.5 + rng.f64() * 0.3;
        let op = OperatingPoint::at_vdd(vdd);
        for cfg in [PrecisionConfig::Uniform8, PrecisionConfig::Mixed] {
            let rep = s.network_report(&resnet20_layers(cfg), &op).unwrap();
            for l in &rep.layers {
                let max =
                    l.off_us.max(l.onchip_us).max(l.exec_us);
                assert!((l.latency_us - max).abs() < 1e-9, "{}", l.name);
                assert!(l.energy_uj.is_finite() && l.energy_uj > 0.0);
            }
        }
    }
}

/// RBE model physics under random jobs: cycles are positive, monotone in
/// W for 3x3 (weight bits serialized), invariant in W for 1x1, and the
/// functional bit-serial output equals the integer oracle.
#[test]
fn rbe_random_job_sweep() {
    let mut rng = Rng::new(3);
    for _ in 0..40 {
        let mode = if rng.f64() < 0.5 {
            RbeMode::Conv3x3
        } else {
            RbeMode::Conv1x1
        };
        let job = RbeJob {
            mode,
            h_out: 1 + rng.index(4),
            w_out: 1 + rng.index(4),
            k_in: *rng.pick(&[1, 3, 16, 32]),
            k_out: *rng.pick(&[2, 8, 32]),
            stride: 1 + rng.index(2),
            w_bits: 2 + rng.index(7),
            i_bits: 2 + rng.index(7),
            o_bits: 2 + rng.index(7),
        };
        assert!(RbeTiming::cycles(&job) > 0);
        // W monotonicity
        if job.w_bits < 8 {
            let mut heavier = job;
            heavier.w_bits += 1;
            match mode {
                RbeMode::Conv3x3 => assert!(
                    RbeTiming::cycles(&heavier) > RbeTiming::cycles(&job)
                ),
                RbeMode::Conv1x1 => assert_eq!(
                    RbeTiming::cycles(&heavier),
                    RbeTiming::cycles(&job)
                ),
            }
        }
        // functional equivalence on small jobs
        if job.h_out * job.w_out * job.k_in * job.k_out < 4096 {
            let taps = if mode == RbeMode::Conv3x3 { 9 } else { 1 };
            let x: Vec<i32> = (0..job.h_in() * job.w_in() * job.k_in)
                .map(|_| rng.range_i32(0, 1 << job.i_bits))
                .collect();
            let wh = 1 << (job.w_bits - 1);
            let w: Vec<i32> = (0..job.k_out * job.k_in * taps)
                .map(|_| rng.range_i32(-wh, wh))
                .collect();
            let nq = NormQuant::unit(job.k_out);
            assert_eq!(
                conv_bitserial(&job, &x, &w, &nq).unwrap(),
                conv_reference(&job, &x, &w, &nq).unwrap(),
                "{job:?}"
            );
        }
    }
}

/// ISS matmul correctness across random shapes/kernels (the end-to-end
/// "programs compute the right numbers" property).
#[test]
fn iss_matmul_random_shapes() {
    let mut rng = Rng::new(4);
    for trial in 0..10 {
        let cores = *rng.pick(&[1usize, 2, 4]);
        let kernel = *rng.pick(&[
            MatmulKernel::Xpulp8,
            MatmulKernel::Nn { prec: Prec::B4 },
            MatmulKernel::MacLoad { prec: Prec::B8 },
            MatmulKernel::MacLoad { prec: Prec::B2 },
        ]);
        let m = 4 * cores * (1 + rng.index(3));
        let n = 4 * (1 + rng.index(4));
        let lanes = kernel.prec().lanes() as usize;
        let k = lanes * (2 + rng.index(6));
        let p = MatmulProblem { m, n, k, kernel, cores };
        let (a, b) = random_operands(m, n, k, kernel.prec(), trial as u64);
        let mut cfg = ClusterConfig::default();
        cfg.cores = cores;
        let (c, stats) = p.run_with(cfg, &a, &b).unwrap();
        assert_eq!(c, matmul_reference(m, n, k, &a, &b),
                   "{kernel:?} m{m} n{n} k{k} cores{cores}");
        assert_eq!(stats.total.macs, p.macs());
    }
}

/// Fault injection: a program touching unmapped memory aborts the
/// simulation with an error instead of corrupting state.
#[test]
fn unmapped_access_faults() {
    let mut b = ProgramBuilder::new("fault", IsaLevel::Xpulp);
    b.emit(Instr::Li { rd: 5, imm: 0x0060_0000 }); // not TCDM, not L2
    b.emit(Instr::Lw { rd: 6, base: 5, offset: 0, post_inc: 0 });
    let mut cl = Cluster::new(ClusterConfig::soc_controller());
    cl.load_spmd(b.build().unwrap());
    let err = cl.run().unwrap_err().to_string();
    assert!(err.contains("unmapped"), "{err}");
}

/// Fault injection: runaway programs hit the cycle limit.
#[test]
fn runaway_program_hits_cycle_limit() {
    let mut b = ProgramBuilder::new("spin", IsaLevel::Xpulp);
    let top = b.label();
    b.bind(top);
    b.emit(Instr::AluImm { op: AluOp::Add, rd: 5, rs1: 5, imm: 1 });
    b.jump(top);
    let mut cfg = ClusterConfig::soc_controller();
    cfg.max_cycles = 10_000;
    let mut cl = Cluster::new(cfg);
    cl.load_spmd(b.build().unwrap());
    assert!(cl.run().is_err());
}

/// Disassembly smoke: every instruction of a real kernel renders and the
/// MAC&LOAD inner loop appears with the documented 16+1 structure.
#[test]
fn disassembly_of_macload_kernel() {
    let p = MatmulProblem {
        m: 16,
        n: 8,
        k: 32,
        kernel: MatmulKernel::MacLoad { prec: Prec::B4 },
        cores: 4,
    };
    let mut alloc = marsellus::kernels::TcdmAlloc::new();
    let built = p.build(&mut alloc).unwrap();
    let text = disasm::disassemble(&built.prog.instrs);
    assert_eq!(text.matches("pv.mlsdotps.n").count(), 16);
    assert_eq!(text.matches("p.nnlw").count(), 6); // 5 warm-up + 1 in-loop
    assert!(text.contains("lp.setup"));
}

/// Power-model physics: monotone in V at fixed workload/frequency, and
/// FBB always costs leakage.
#[test]
fn power_model_monotonicity() {
    let m = PowerModel;
    let mut rng = Rng::new(5);
    for _ in 0..100 {
        let v = 0.5 + rng.f64() * 0.3;
        let f = 50.0 + rng.f64() * 300.0;
        let w = *rng.pick(&[
            Workload::MatmulXpulp8,
            Workload::MatmulMacLoad,
            Workload::Rbe { duty_pct: 100 },
            Workload::Idle,
        ]);
        let lo = OperatingPoint { vdd: v, freq_mhz: f, fbb_v: 0.0 };
        let hi = OperatingPoint { vdd: v + 0.05, freq_mhz: f, fbb_v: 0.0 };
        assert!(m.total_mw(w, &hi) > m.total_mw(w, &lo));
        let fbb = OperatingPoint { vdd: v, freq_mhz: f, fbb_v: 0.5 };
        assert!(m.leakage_mw(&fbb) > m.leakage_mw(&lo));
        // and fmax is monotone in fbb
        assert!(fmax_mhz(v, 0.5) >= fmax_mhz(v, 0.0));
    }
}

/// TCDM data integrity under the full 16-core conflict stress of the
/// engine test suite: stores from all cores land (no lost updates).
#[test]
fn no_lost_updates_under_contention() {
    let mut b = ProgramBuilder::new("stress", IsaLevel::Xpulp);
    // each core increments its own counter 100 times at stride 1 word
    // (all in the same bank region to force arbitration churn)
    b.emit(Instr::CoreId { rd: 5 });
    b.emit(Instr::AluImm { op: AluOp::Sll, rd: 5, rs1: 5, imm: 2 });
    b.emit(Instr::AluImm {
        op: AluOp::Add,
        rd: 5,
        rs1: 5,
        imm: TCDM_BASE as i32,
    });
    b.emit(Instr::Li { rd: 7, imm: 100 });
    let (ls, le) = (b.label(), b.label());
    b.hw_loop(0, 7, ls, le);
    b.bind(ls);
    b.emit(Instr::Lw { rd: 6, base: 5, offset: 0, post_inc: 0 });
    b.emit(Instr::AluImm { op: AluOp::Add, rd: 6, rs1: 6, imm: 1 });
    b.emit(Instr::Sw { rs: 6, base: 5, offset: 0, post_inc: 0 });
    b.bind(le);
    let mut cl = Cluster::new(ClusterConfig::default());
    cl.load_spmd(b.build().unwrap());
    cl.run().unwrap();
    for c in 0..16 {
        assert_eq!(cl.mem.l1[c], 100, "core {c} counter");
    }
}
