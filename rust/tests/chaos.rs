//! Fault-injection regression tests (`--features chaos`): a panicking
//! request must leave the gateway fully accounted — latency and
//! deadline telemetry recorded, inflight slot released — and a seeded
//! chaos storm over a 2-tenant trace must resolve every ticket to
//! exactly one typed outcome with counters reconciling exactly and
//! completed logits bitwise equal to the direct path.

#![cfg(all(feature = "chaos", feature = "native"))]

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use marsellus::analysis::failpoint::{
    arm_once, arm_seed, disarm_all, FailAction,
};
use marsellus::coordinator::Coordinator;
use marsellus::dnn::{NetworkSpec, PrecisionConfig};
use marsellus::gateway::{
    pick_schedule, CancelOutcome, Gateway, GatewayConfig, Priority,
    ServeError,
};
use marsellus::power::OperatingPoint;
use marsellus::runtime::{global, ExecRuntime, Runtime};
use marsellus::util::Rng;

/// The failpoint registry is process-global; serialize the tests that
/// arm it.
fn serial() -> MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn coordinator() -> Arc<Coordinator> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    let rt = Runtime::native(&dir).expect("native runtime");
    Arc::new(Coordinator::with_runtime(rt).expect("coordinator"))
}

fn kws(seed: u64) -> NetworkSpec {
    NetworkSpec::new("kws", PrecisionConfig::Mixed, seed)
}

fn op() -> OperatingPoint {
    OperatingPoint::at_vdd(0.8)
}

/// An injected panic inside inference is delivered as a typed
/// `ServeError::Panicked`, records end-to-end latency and deadline
/// telemetry like any other terminal transition, and releases the
/// tenant's inflight slot — proven by re-admitting the same tenant
/// under an inflight cap of 1.
#[test]
fn dispatcher_panic_records_latency_and_releases_slot() {
    let _g = serial();
    disarm_all();

    let coord = coordinator();
    let spec = kws(20);
    let d = coord.deploy(&spec).unwrap();
    let mut rng = Rng::new(70);
    let img = d.random_input(&mut rng);

    // serve-anyway mode so the 1ns deadline reaches the (panicking)
    // serve path instead of the reaper
    let gateway = Gateway::new(coord.clone(), GatewayConfig {
        queue_depth: 16,
        per_tenant_inflight: 1,
        threads: 2,
        shed_expired: false,
        ..GatewayConfig::default()
    })
    .unwrap();

    arm_once("dispatch::serve", FailAction::Panic);
    let err = gateway
        .submit(
            "t",
            &spec,
            &op(),
            vec![img.clone()],
            Priority::Normal,
            Some(Duration::from_nanos(1)),
        )
        .expect("admitted")
        .wait()
        .expect_err("injected panic must surface as an error");
    match err.downcast_ref::<ServeError>() {
        Some(ServeError::Panicked { id: _, msg }) => {
            assert!(msg.contains("injected panic"), "got: {msg}");
        }
        other => panic!("expected Panicked, got {other:?}"),
    }
    let snap = gateway.telemetry().snapshot();
    assert_eq!(snap.panicked, 1);
    assert_eq!(snap.completed, 0);
    assert_eq!(
        snap.deadline_missed, 1,
        "a panicked request still records its deadline outcome"
    );
    assert!(snap.reconciles(), "counters must reconcile: {snap:?}");

    // failpoint was one-shot: the same tenant (inflight cap 1) admits
    // and completes, proving the panic released its slot
    gateway
        .submit("t", &spec, &op(), vec![img], Priority::Normal, None)
        .expect("panic must release the tenant's inflight slot")
        .wait()
        .expect("disarmed path serves normally");
    assert_eq!(gateway.telemetry().snapshot().completed, 1);
    disarm_all();
}

/// Seeded chaos storm over a 2-tenant request mix with caller-side
/// cancellations: every ticket resolves to exactly one typed outcome
/// (no stranded waiter), counters reconcile exactly, every completed
/// result is bitwise equal to the direct path, and the storm spawns
/// zero threads.
#[test]
fn chaos_storm_reconciles_and_stays_bitwise() {
    let _g = serial();
    disarm_all();

    let coord = coordinator();
    let spec = kws(21);
    let d = coord.deploy(&spec).unwrap();
    let mut rng = Rng::new(71);
    let sizes = [1usize, 2, 3, 1, 2, 3, 1, 2, 3, 1, 2, 3];
    let batches: Vec<Vec<Vec<i32>>> = sizes
        .iter()
        .map(|&n| (0..n).map(|_| d.random_input(&mut rng)).collect())
        .collect();

    // direct-path reference (also warms the fleet so the spawn counter
    // below measures the storm, not provisioning)
    let width = global().width();
    let direct: Vec<Vec<Vec<i32>>> = batches
        .iter()
        .map(|imgs| {
            d.infer_scheduled_on(
                &op(),
                imgs,
                pick_schedule(imgs.len(), width),
                ExecRuntime::Global,
            )
            .unwrap()
            .into_iter()
            .map(|r| r.logits)
            .collect()
        })
        .collect();
    let spawned_before = global().telemetry().spawned_threads;

    arm_seed(0xC0FFEE);
    let gateway = Gateway::new(coord.clone(), GatewayConfig {
        queue_depth: 32,
        per_tenant_inflight: 32,
        threads: 2,
        ..GatewayConfig::default()
    })
    .unwrap();
    let tickets: Vec<_> = batches
        .iter()
        .enumerate()
        .map(|(i, imgs)| {
            let tenant = if i % 2 == 0 { "alpha" } else { "beta" };
            let prio =
                if i % 3 == 0 { Priority::High } else { Priority::Normal };
            // far deadlines: only the seeded reaper sheds
            gateway
                .submit(
                    tenant,
                    &spec,
                    &op(),
                    imgs.clone(),
                    prio,
                    Some(Duration::from_secs(60)),
                )
                .expect("admission is not under chaos here")
        })
        .collect();
    // caller-side cancellations racing the dispatcher: either outcome
    // of the race is legal, both must stay accounted
    for (i, t) in tickets.iter().enumerate() {
        if i % 5 == 0 {
            match t.cancel() {
                CancelOutcome::Cancelled
                | CancelOutcome::AlreadyStarted => {}
            }
        }
    }

    let (mut ok, mut cancelled, mut shed, mut panicked) = (0u64, 0, 0, 0);
    for (i, t) in tickets.into_iter().enumerate() {
        // the invariant under test: wait() always resolves, to exactly
        // one typed outcome
        match t.wait() {
            Ok(done) => {
                let logits: Vec<Vec<i32>> = done
                    .results
                    .into_iter()
                    .map(|r| r.logits)
                    .collect();
                assert_eq!(
                    logits, direct[i],
                    "request {i}: chaos changed the bits"
                );
                ok += 1;
            }
            Err(e) => match e.downcast_ref::<ServeError>() {
                Some(ServeError::Cancelled { .. }) => cancelled += 1,
                Some(ServeError::DeadlineExceeded { .. }) => shed += 1,
                Some(ServeError::Panicked { .. }) => panicked += 1,
                None => panic!("untyped failure under chaos: {e:#}"),
            },
        }
    }
    disarm_all();

    let snap = gateway.telemetry().snapshot();
    assert_eq!(snap.submitted, 12);
    assert_eq!(snap.admitted, 12);
    assert_eq!(snap.completed, ok);
    assert_eq!(snap.cancelled, cancelled);
    assert_eq!(snap.shed, shed);
    assert_eq!(snap.panicked, panicked);
    assert_eq!(snap.failed, 0);
    assert!(snap.reconciles(), "lifecycle identity broken: {snap:?}");
    assert_eq!(
        global().telemetry().spawned_threads,
        spawned_before,
        "the chaos storm must spawn zero worker threads"
    );
}
