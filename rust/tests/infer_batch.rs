//! Batch-serving determinism: `Coordinator::infer_batch` must produce
//! bitwise-identical logits regardless of batch size or worker-thread
//! count (acceptance criterion: batch=1 vs batch=8 on the same seed).

#![cfg(feature = "native")]

use marsellus::coordinator::{random_image, Coordinator};
use marsellus::dnn::PrecisionConfig;
use marsellus::power::OperatingPoint;
use marsellus::runtime::Runtime;
use marsellus::util::Rng;

fn coordinator() -> Coordinator {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    let rt = Runtime::native(&dir).expect("native runtime");
    Coordinator::with_runtime(rt).expect("coordinator")
}

#[test]
fn batch_of_1_equals_batch_of_8() {
    let coord = coordinator();
    let op = OperatingPoint::at_vdd(0.8);
    let mut rng = Rng::new(10);
    let images: Vec<Vec<i32>> =
        (0..8).map(|_| random_image(8, &mut rng)).collect();

    // batch of 8 across 4 threads, same seed (= same deployed weights)
    let batch = coord
        .infer_batch(PrecisionConfig::Mixed, &op, &images, 42, 4)
        .unwrap();
    assert_eq!(batch.len(), 8);

    // every image individually (batch of 1, single-threaded)
    for (i, img) in images.iter().enumerate() {
        let solo = coord
            .infer_batch(
                PrecisionConfig::Mixed,
                &op,
                std::slice::from_ref(img),
                42,
                1,
            )
            .unwrap();
        assert_eq!(
            solo[0].logits, batch[i].logits,
            "image {i}: batch=1 vs batch=8 logits diverged"
        );
    }
}

#[test]
fn thread_count_does_not_change_results() {
    let coord = coordinator();
    let op = OperatingPoint::at_vdd(0.8);
    let mut rng = Rng::new(11);
    let images: Vec<Vec<i32>> =
        (0..5).map(|_| random_image(8, &mut rng)).collect();
    let base = coord
        .infer_batch(PrecisionConfig::Uniform8, &op, &images, 7, 1)
        .unwrap();
    for threads in [2, 3, 8] {
        let got = coord
            .infer_batch(PrecisionConfig::Uniform8, &op, &images, 7, threads)
            .unwrap();
        for (a, b) in base.iter().zip(&got) {
            assert_eq!(a.logits, b.logits, "{threads} threads");
        }
    }
    // oversubscription beyond the batch size is clamped, not an error
    let clamped = coord
        .infer_batch(PrecisionConfig::Uniform8, &op, &images[..2], 7, 64)
        .unwrap();
    assert_eq!(clamped.len(), 2);
    assert_eq!(clamped[0].logits, base[0].logits);
}

#[test]
fn batch_shares_one_compile_cache() {
    let coord = coordinator();
    let op = OperatingPoint::at_vdd(0.8);
    let mut rng = Rng::new(12);
    let images: Vec<Vec<i32>> =
        (0..4).map(|_| random_image(8, &mut rng)).collect();
    // warm the cache sequentially (no compile races), then fan out
    coord
        .infer_batch(PrecisionConfig::Mixed, &op, &images[..1], 1, 1)
        .unwrap();
    // the mixed net has 13 distinct artifact names (repeated residual
    // blocks share executables — that's the point of the cache)
    let distinct = coord.runtime.cached_executables() as u64;
    assert!(distinct >= 12, "{distinct} executables cached");
    assert_eq!(coord.runtime.cache_misses(), distinct);

    coord
        .infer_batch(PrecisionConfig::Mixed, &op, &images, 1, 4)
        .unwrap();
    // warm cache: the threaded batch must compile nothing new
    assert_eq!(coord.runtime.cache_misses(), distinct, "cache not shared");
    assert!(coord.runtime.cache_hits() > coord.runtime.cache_misses());
}

#[test]
fn empty_batch_is_ok() {
    let coord = coordinator();
    let out = coord
        .infer_batch(
            PrecisionConfig::Mixed,
            &OperatingPoint::at_vdd(0.8),
            &[],
            42,
            4,
        )
        .unwrap();
    assert!(out.is_empty());
}
