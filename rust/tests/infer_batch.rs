//! Batch-serving determinism: `Coordinator::infer_batch` must produce
//! bitwise-identical logits regardless of batch size or worker-thread
//! count (acceptance criterion: batch=1 vs batch=8 on the same seed),
//! and the precompiled-LayerPlan parallel path must be bitwise identical
//! to sequential per-call execution across 1/4/16 worker threads.

#![cfg(feature = "native")]

use marsellus::coordinator::{random_image, Coordinator};
use marsellus::dnn::PrecisionConfig;
use marsellus::power::OperatingPoint;
use marsellus::runtime::Runtime;
use marsellus::util::Rng;

fn coordinator() -> Coordinator {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    let rt = Runtime::native(&dir).expect("native runtime");
    Coordinator::with_runtime(rt).expect("coordinator")
}

#[test]
fn batch_of_1_equals_batch_of_8() {
    let coord = coordinator();
    let op = OperatingPoint::at_vdd(0.8);
    let mut rng = Rng::new(10);
    let images: Vec<Vec<i32>> =
        (0..8).map(|_| random_image(8, &mut rng)).collect();

    // batch of 8 across 4 threads, same seed (= same deployed weights)
    let batch = coord
        .infer_batch(PrecisionConfig::Mixed, &op, &images, 42, 4)
        .unwrap();
    assert_eq!(batch.len(), 8);

    // every image individually (batch of 1, single-threaded)
    for (i, img) in images.iter().enumerate() {
        let solo = coord
            .infer_batch(
                PrecisionConfig::Mixed,
                &op,
                std::slice::from_ref(img),
                42,
                1,
            )
            .unwrap();
        assert_eq!(
            solo[0].logits, batch[i].logits,
            "image {i}: batch=1 vs batch=8 logits diverged"
        );
    }
}

#[test]
fn thread_count_does_not_change_results() {
    let coord = coordinator();
    let op = OperatingPoint::at_vdd(0.8);
    let mut rng = Rng::new(11);
    let images: Vec<Vec<i32>> =
        (0..5).map(|_| random_image(8, &mut rng)).collect();
    let base = coord
        .infer_batch(PrecisionConfig::Uniform8, &op, &images, 7, 1)
        .unwrap();
    for threads in [2, 3, 8] {
        let got = coord
            .infer_batch(PrecisionConfig::Uniform8, &op, &images, 7, threads)
            .unwrap();
        for (a, b) in base.iter().zip(&got) {
            assert_eq!(a.logits, b.logits, "{threads} threads");
        }
    }
    // oversubscription beyond the batch size is clamped, not an error
    let clamped = coord
        .infer_batch(PrecisionConfig::Uniform8, &op, &images[..2], 7, 64)
        .unwrap();
    assert_eq!(clamped.len(), 2);
    assert_eq!(clamped[0].logits, base[0].logits);
}

#[test]
fn batch_shares_one_compile_cache() {
    // the per-call (pre-plan) path exercises the artifact compile cache
    let coord = coordinator();
    let op = OperatingPoint::at_vdd(0.8);
    let mut rng = Rng::new(12);
    let images: Vec<Vec<i32>> =
        (0..4).map(|_| random_image(8, &mut rng)).collect();
    // warm the cache sequentially (no compile races), then fan out
    coord
        .infer_batch_opts(PrecisionConfig::Mixed, &op, &images[..1], 1, 1, false)
        .unwrap();
    // the mixed net has 13 distinct artifact names (repeated residual
    // blocks share executables — that's the point of the cache)
    let distinct = coord.runtime.cached_executables() as u64;
    assert!(distinct >= 12, "{distinct} executables cached");
    assert_eq!(coord.runtime.cache_misses(), distinct);

    coord
        .infer_batch_opts(PrecisionConfig::Mixed, &op, &images, 1, 4, false)
        .unwrap();
    // warm cache: the threaded batch must compile nothing new
    assert_eq!(coord.runtime.cache_misses(), distinct, "cache not shared");
    assert!(coord.runtime.cache_hits() > coord.runtime.cache_misses());
}

/// Acceptance criterion of the LayerPlan PR: the parallel plan-driven
/// native path is bitwise identical to sequential per-call execution,
/// across 1, 4 and 16 worker threads.
#[test]
fn parallel_plan_path_matches_sequential_per_call_path() {
    let coord = coordinator();
    let op = OperatingPoint::at_vdd(0.8);
    let mut rng = Rng::new(13);
    let images: Vec<Vec<i32>> =
        (0..3).map(|_| random_image(8, &mut rng)).collect();
    // pre-plan baseline: sequential, per-call backend execution
    let base = coord
        .infer_batch_opts(PrecisionConfig::Mixed, &op, &images, 5, 1, false)
        .unwrap();
    for threads in [1usize, 4, 16] {
        let got = coord
            .infer_batch(PrecisionConfig::Mixed, &op, &images, 5, threads)
            .unwrap();
        for (i, (a, b)) in base.iter().zip(&got).enumerate() {
            assert_eq!(
                a.logits, b.logits,
                "image {i}: plan path with {threads} threads diverged \
                 from sequential per-call execution"
            );
        }
    }
    // the plan path never touched the per-artifact compile cache beyond
    // what the baseline compiled
    assert_eq!(coord.runtime.plan_builds(), 1, "one deployment, one plan");
}

/// Plan caching: repeated execution of the same deployment reuses the
/// compiled plan (no rebuild) and yields identical logits; a different
/// weight seed is a different deployment and compiles a fresh plan.
#[test]
fn plan_cache_reused_across_repeated_executes() {
    let coord = coordinator();
    let op = OperatingPoint::at_vdd(0.8);
    let mut rng = Rng::new(14);
    let images: Vec<Vec<i32>> =
        (0..2).map(|_| random_image(8, &mut rng)).collect();
    let a = coord
        .infer_batch(PrecisionConfig::Uniform8, &op, &images, 9, 2)
        .unwrap();
    assert_eq!(coord.runtime.plan_builds(), 1);
    assert_eq!(coord.runtime.cached_plans(), 1);
    let b = coord
        .infer_batch(PrecisionConfig::Uniform8, &op, &images, 9, 2)
        .unwrap();
    assert_eq!(
        coord.runtime.plan_builds(),
        1,
        "second execute of the same deployment rebuilt the plan"
    );
    assert!(coord.runtime.plan_hits() >= 1);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.logits, y.logits, "cached plan changed the logits");
    }
    // a new seed deploys new weights: fresh plan, (almost surely) new logits
    let c = coord
        .infer_batch(PrecisionConfig::Uniform8, &op, &images, 10, 2)
        .unwrap();
    assert_eq!(coord.runtime.plan_builds(), 2);
    assert_ne!(a[0].logits, c[0].logits);
}

#[test]
fn empty_batch_is_ok() {
    let coord = coordinator();
    let out = coord
        .infer_batch(
            PrecisionConfig::Mixed,
            &OperatingPoint::at_vdd(0.8),
            &[],
            42,
            4,
        )
        .unwrap();
    assert!(out.is_empty());
}
