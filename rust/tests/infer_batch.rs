//! Batch-serving determinism through the deployment API:
//! `Deployment::infer_batch` must produce bitwise-identical logits
//! regardless of batch size or worker-thread count (batch=1 vs batch=8
//! on the same spec), and the precompiled-plan parallel path must be
//! bitwise identical to sequential per-call execution across 1/4/16
//! worker threads. The presets (`infer_batch`, `profile`) are pinned to
//! the one `infer_scheduled` path they narrow to.

#![cfg(feature = "native")]

use marsellus::coordinator::{Coordinator, Schedule};
use marsellus::dnn::{NetworkSpec, PrecisionConfig};
use marsellus::power::OperatingPoint;
use marsellus::runtime::Runtime;
use marsellus::util::Rng;

fn coordinator() -> Coordinator {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    let rt = Runtime::native(&dir).expect("native runtime");
    Coordinator::with_runtime(rt).expect("coordinator")
}

fn spec(config: PrecisionConfig, seed: u64) -> NetworkSpec {
    NetworkSpec::new("resnet20", config, seed)
}

#[test]
fn batch_of_1_equals_batch_of_8() {
    let coord = coordinator();
    let op = OperatingPoint::at_vdd(0.8);
    let d = coord.deploy(&spec(PrecisionConfig::Mixed, 42)).unwrap();
    let mut rng = Rng::new(10);
    let images: Vec<Vec<i32>> =
        (0..8).map(|_| d.random_input(&mut rng)).collect();

    // batch of 8 across 4 threads against the one deployed model
    let batch = d.infer_batch(&op, &images, 4).unwrap();
    assert_eq!(batch.len(), 8);

    // every image individually (batch of 1, single-threaded)
    for (i, img) in images.iter().enumerate() {
        let solo = d
            .infer_batch(&op, std::slice::from_ref(img), 1)
            .unwrap();
        assert_eq!(
            solo[0].logits, batch[i].logits,
            "image {i}: batch=1 vs batch=8 logits diverged"
        );
        // and the single-input entry point agrees with both
        let one = d.infer(&op, img).unwrap();
        assert_eq!(one.logits, batch[i].logits, "image {i}: infer diverged");
    }
}

#[test]
fn thread_count_does_not_change_results() {
    let coord = coordinator();
    let op = OperatingPoint::at_vdd(0.8);
    let d = coord.deploy(&spec(PrecisionConfig::Uniform8, 7)).unwrap();
    let mut rng = Rng::new(11);
    let images: Vec<Vec<i32>> =
        (0..5).map(|_| d.random_input(&mut rng)).collect();
    let base = d.infer_batch(&op, &images, 1).unwrap();
    for threads in [2, 3, 8] {
        let got = d.infer_batch(&op, &images, threads).unwrap();
        for (a, b) in base.iter().zip(&got) {
            assert_eq!(a.logits, b.logits, "{threads} threads");
        }
    }
    // oversubscription beyond the batch size is clamped, not an error
    let clamped = d.infer_batch(&op, &images[..2], 64).unwrap();
    assert_eq!(clamped.len(), 2);
    assert_eq!(clamped[0].logits, base[0].logits);
}

#[test]
fn batch_shares_one_compile_cache() {
    // the per-call (pre-plan) path exercises the artifact compile cache
    let coord = coordinator();
    let op = OperatingPoint::at_vdd(0.8);
    let d = coord.deploy(&spec(PrecisionConfig::Mixed, 1)).unwrap();
    let mut rng = Rng::new(12);
    let images: Vec<Vec<i32>> =
        (0..4).map(|_| d.random_input(&mut rng)).collect();
    // warm the cache sequentially (no compile races), then fan out
    d.infer_batch_opts(&op, &images[..1], 1, false).unwrap();
    // the mixed net has 13 distinct artifact names (repeated residual
    // blocks share executables — that's the point of the cache)
    let distinct = coord.runtime.cached_executables() as u64;
    assert!(distinct >= 12, "{distinct} executables cached");
    assert_eq!(coord.runtime.cache_misses(), distinct);

    d.infer_batch_opts(&op, &images, 4, false).unwrap();
    // warm cache: the threaded batch must compile nothing new
    assert_eq!(coord.runtime.cache_misses(), distinct, "cache not shared");
    assert!(coord.runtime.cache_hits() > coord.runtime.cache_misses());
}

/// Acceptance criterion of the LayerPlan PR, restated over the handle
/// API: the parallel plan-driven native path is bitwise identical to
/// sequential per-call execution, across 1, 4 and 16 worker threads.
#[test]
fn parallel_plan_path_matches_sequential_per_call_path() {
    let coord = coordinator();
    let op = OperatingPoint::at_vdd(0.8);
    let d = coord.deploy(&spec(PrecisionConfig::Mixed, 5)).unwrap();
    let mut rng = Rng::new(13);
    let images: Vec<Vec<i32>> =
        (0..3).map(|_| d.random_input(&mut rng)).collect();
    // pre-plan baseline: sequential, per-call backend execution
    let base = d.infer_batch_opts(&op, &images, 1, false).unwrap();
    for threads in [1usize, 4, 16] {
        let got = d.infer_batch(&op, &images, threads).unwrap();
        for (i, (a, b)) in base.iter().zip(&got).enumerate() {
            assert_eq!(
                a.logits, b.logits,
                "image {i}: plan path with {threads} threads diverged \
                 from sequential per-call execution"
            );
        }
    }
    assert_eq!(coord.runtime.plan_builds(), 1, "one deployment, one plan");
}

/// Plan caching: re-deploying the same spec reuses the compiled plan
/// (no rebuild) and yields identical logits; a different weight seed is
/// a different deployment and compiles a fresh plan.
#[test]
fn plan_cache_reused_across_repeated_deploys() {
    let coord = coordinator();
    let op = OperatingPoint::at_vdd(0.8);
    let d = coord.deploy(&spec(PrecisionConfig::Uniform8, 9)).unwrap();
    let mut rng = Rng::new(14);
    let images: Vec<Vec<i32>> =
        (0..2).map(|_| d.random_input(&mut rng)).collect();
    let a = d.infer_batch(&op, &images, 2).unwrap();
    assert_eq!(coord.runtime.plan_builds(), 1);
    assert_eq!(coord.runtime.cached_plans(), 1);
    let d2 = coord.deploy(&spec(PrecisionConfig::Uniform8, 9)).unwrap();
    let b = d2.infer_batch(&op, &images, 2).unwrap();
    assert_eq!(
        coord.runtime.plan_builds(),
        1,
        "re-deploying the same spec rebuilt the plan"
    );
    assert!(coord.runtime.plan_hits() >= 1);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.logits, y.logits, "cached plan changed the logits");
    }
    // a new seed deploys new weights: fresh plan, (almost surely) new logits
    let d3 = coord.deploy(&spec(PrecisionConfig::Uniform8, 10)).unwrap();
    let c = d3.infer_batch(&op, &images, 2).unwrap();
    assert_eq!(coord.runtime.plan_builds(), 2);
    assert_ne!(a[0].logits, c[0].logits);
}

#[test]
fn empty_batch_is_ok() {
    let coord = coordinator();
    let d = coord.deploy(&spec(PrecisionConfig::Mixed, 42)).unwrap();
    let out = d
        .infer_batch(&OperatingPoint::at_vdd(0.8), &[], 4)
        .unwrap();
    assert!(out.is_empty());
}

/// The presets stay pinned to the one serving path they narrow to:
/// `infer_batch(threads)` equals `infer_scheduled(Schedule::batch)`,
/// the single-input `infer` agrees with both, and `profile` reports one
/// split per layer of the deployed schedule.
#[test]
fn presets_narrow_to_infer_scheduled() {
    let coord = coordinator();
    let op = OperatingPoint::at_vdd(0.8);
    let d = coord.deploy(&spec(PrecisionConfig::Mixed, 3)).unwrap();
    let mut rng = Rng::new(15);
    let images: Vec<Vec<i32>> =
        (0..2).map(|_| d.random_input(&mut rng)).collect();
    let preset = d.infer_batch(&op, &images, 2).unwrap();
    let scheduled =
        d.infer_scheduled(&op, &images, Schedule::batch(2)).unwrap();
    for (a, b) in preset.iter().zip(&scheduled) {
        assert_eq!(a.logits, b.logits);
    }
    let solo = d.infer(&op, &images[0]).unwrap();
    assert_eq!(solo.logits, preset[0].logits);
    let split = d.profile(&images[0]).unwrap();
    assert_eq!(split.len(), d.layers().len());
}
