//! Hybrid batch x tile scheduler acceptance (ISSUE 5): every schedule
//! — now running on the process-wide global runtime by default, with
//! the owned `ExecPool` as the A/B path — must be bitwise identical to
//! the sequential per-call path across the full (batch, threads)
//! matrix, including the signed-head KWS network and layers under the
//! latency-tile MAC floor degrading gracefully inside the pool.
//! `tests/global_runtime.rs` pins Owned-vs-Global parity explicitly.

#![cfg(feature = "native")]

use marsellus::coordinator::{Coordinator, Schedule, ScheduleMode};
use marsellus::dnn::{NetworkSpec, PrecisionConfig};
use marsellus::power::OperatingPoint;
use marsellus::runtime::{ExecRuntime, Runtime, LATENCY_TILE_MIN_MACS};
use marsellus::util::Rng;

fn coordinator() -> Coordinator {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    let rt = Runtime::native(&dir).expect("native runtime");
    Coordinator::with_runtime(rt).expect("coordinator")
}

fn op() -> OperatingPoint {
    OperatingPoint::at_vdd(0.8)
}

const MODES: [ScheduleMode; 4] = [
    ScheduleMode::Auto,
    ScheduleMode::Batch,
    ScheduleMode::Latency,
    ScheduleMode::Hybrid,
];

/// The full acceptance matrix on the signed-head KWS net: batch sizes
/// {1, 3, 8, 17} x threads {1, 4, 16} x every mode, all bitwise equal
/// to the sequential **per-call** path (not merely plan-vs-plan), with
/// negative logits surviving every schedule.
#[test]
fn kws_matrix_matches_sequential_per_call() {
    let coord = coordinator();
    let d = coord
        .deploy(&NetworkSpec::new("kws", PrecisionConfig::Mixed, 7))
        .unwrap();
    let mut rng = Rng::new(50);
    let mut saw_negative = false;
    for batch in [1usize, 3, 8, 17] {
        let images: Vec<Vec<i32>> =
            (0..batch).map(|_| d.random_input(&mut rng)).collect();
        // sequential per-call reference: 1 thread, pre-plan path
        let want: Vec<Vec<i32>> = d
            .infer_batch_opts(&op(), &images, 1, false)
            .unwrap()
            .into_iter()
            .map(|r| r.logits)
            .collect();
        saw_negative |=
            want.iter().any(|l| l.iter().any(|&v| v < 0));
        for threads in [1usize, 4, 16] {
            for mode in MODES {
                let got: Vec<Vec<i32>> = d
                    .infer_scheduled(
                        &op(),
                        &images,
                        Schedule { threads, mode },
                    )
                    .unwrap()
                    .into_iter()
                    .map(|r| r.logits)
                    .collect();
                assert_eq!(
                    got, want,
                    "kws batch {batch}, {threads} threads, {mode:?} \
                     diverged from sequential per-call"
                );
            }
        }
    }
    assert!(
        saw_negative,
        "no negative logit anywhere — the signed head is not exercised"
    );
}

/// KWS deploys with a layer under the latency-tile MAC floor (the
/// 16x12 head), so the matrix above also proves tiny layers degrade
/// gracefully inside the pool. Pin that premise so a zoo change cannot
/// silently void it.
#[test]
fn kws_plan_contains_a_below_floor_layer() {
    let coord = coordinator();
    let plan = coord
        .plan_for(&NetworkSpec::new("kws", PrecisionConfig::Mixed, 7))
        .unwrap();
    let macs: Vec<u64> = plan
        .steps()
        .iter()
        .filter_map(|s| match &s.plan {
            marsellus::runtime::LayerPlan::Conv(c) => Some(c.job.macs()),
            _ => None,
        })
        .collect();
    assert!(
        macs.iter().any(|&m| m < LATENCY_TILE_MIN_MACS),
        "no conv layer under the tile floor in {macs:?}"
    );
    assert!(
        macs.iter().any(|&m| m >= LATENCY_TILE_MIN_MACS),
        "no conv layer above the tile floor in {macs:?} — the pool \
         would never tile"
    );
}

/// The matrix on ResNet-20 mixed (the wide-word plan path): every
/// (batch, threads, mode) combination equals the sequential plan walk,
/// and the plan walk equals the per-call path.
#[test]
fn resnet20_matrix_matches_sequential() {
    let coord = coordinator();
    let d = coord
        .deploy(&NetworkSpec::new("resnet20", PrecisionConfig::Mixed, 42))
        .unwrap();
    let mut rng = Rng::new(51);
    for batch in [1usize, 3, 8, 17] {
        let images: Vec<Vec<i32>> =
            (0..batch).map(|_| d.random_input(&mut rng)).collect();
        // sequential plan walk as the in-matrix reference...
        let want: Vec<Vec<i32>> = images
            .iter()
            .map(|img| d.infer(&op(), img).unwrap().logits)
            .collect();
        // ...itself pinned to the per-call path on the first image
        let per_call =
            d.infer_batch_opts(&op(), &images[..1], 1, false).unwrap();
        assert_eq!(per_call[0].logits, want[0], "plan vs per-call");
        for threads in [4usize, 16] {
            for mode in [ScheduleMode::Hybrid, ScheduleMode::Auto] {
                let got: Vec<Vec<i32>> = d
                    .infer_scheduled(
                        &op(),
                        &images,
                        Schedule { threads, mode },
                    )
                    .unwrap()
                    .into_iter()
                    .map(|r| r.logits)
                    .collect();
                assert_eq!(
                    got, want,
                    "resnet20 batch {batch}, {threads} threads, {mode:?}"
                );
            }
        }
    }
}

/// The presets stay thin wrappers: `infer_batch` == Batch schedule,
/// `infer_latency` == Latency schedule on a 1-image batch, and the
/// legacy respawn tiler agrees with both.
#[test]
fn presets_equal_their_schedules() {
    let coord = coordinator();
    let d = coord
        .deploy(&NetworkSpec::new("resnet20", PrecisionConfig::Mixed, 5))
        .unwrap();
    let mut rng = Rng::new(52);
    let images: Vec<Vec<i32>> =
        (0..5).map(|_| d.random_input(&mut rng)).collect();
    let batch = d.infer_batch(&op(), &images, 4).unwrap();
    let sched = d
        .infer_scheduled(&op(), &images, Schedule::batch(4))
        .unwrap();
    for (a, b) in batch.iter().zip(&sched) {
        assert_eq!(a.logits, b.logits, "infer_batch vs Schedule::batch");
    }
    let lat = d.infer_latency(&op(), &images[0], 4).unwrap();
    let lat_sched = d
        .infer_scheduled(&op(), &images[..1], Schedule::latency(4))
        .unwrap();
    assert_eq!(lat.logits, lat_sched[0].logits);
    let respawn =
        d.infer_latency_opts(&op(), &images[0], 4, false).unwrap();
    assert_eq!(lat.logits, respawn.logits, "pooled vs respawn tiler");
}

/// Pool telemetry through `profile_scheduled_on`: the owned A/B pool
/// provisions `threads - 1` workers for many per-layer jobs, the global
/// runtime spawns nothing per call, and the per-layer split carries the
/// activation-packing share.
#[test]
fn profile_reports_pool_telemetry_and_pack_split() {
    let coord = coordinator();
    let d = coord
        .deploy(&NetworkSpec::new("resnet20", PrecisionConfig::Mixed, 9))
        .unwrap();
    let mut rng = Rng::new(53);
    let image = d.random_input(&mut rng);
    // owned pool: provisioning is per call and visible in the telemetry
    let (split, pool) =
        d.profile_scheduled_on(&image, 4, ExecRuntime::Owned).unwrap();
    assert_eq!(split.len(), d.layers().len());
    assert!(pool.width >= 2, "pool collapsed: {pool:?}");
    assert_eq!(pool.spawned_threads, pool.width - 1);
    // every tiled conv layer streams 2 jobs (pack bands + conv tiles);
    // at least the wide body layers must have gone through the pool
    assert!(pool.jobs >= 2, "{pool:?}");
    let packed: f64 = split.iter().map(|l| l.pack_us).sum();
    assert!(packed > 0.0, "no packing time recorded in {split:?}");
    for l in &split {
        assert!(
            l.pack_us <= l.compute_us,
            "{}: pack {} > compute {}",
            l.name,
            l.pack_us,
            l.compute_us
        );
    }
    // sequential profile records the pack share too, with no pool
    let (seq_split, seq_pool) = d.profile_scheduled(&image, 1).unwrap();
    assert_eq!(seq_pool.spawned_threads, 0);
    assert_eq!(seq_pool.jobs, 0);
    assert!(seq_split.iter().map(|l| l.pack_us).sum::<f64>() > 0.0);
    // global runtime: warm it once, then repeated profiling calls must
    // not provision any thread — jobs stream onto the shared workers
    let _ = d
        .profile_scheduled_on(&image, 4, ExecRuntime::Global)
        .unwrap();
    let (g_split, g_pool) = d
        .profile_scheduled_on(&image, 4, ExecRuntime::Global)
        .unwrap();
    assert_eq!(g_split.len(), d.layers().len());
    assert_eq!(
        g_pool.spawned_threads, 0,
        "global runtime spawned per call: {g_pool:?}"
    );
    if g_pool.width > 1 {
        assert!(g_pool.jobs >= 2, "{g_pool:?}");
    }
}

/// Degenerate schedules are serviced, not errors: empty batches are a
/// clean no-op and 0 threads degrades to the sequential walk.
#[test]
fn schedule_edge_cases() {
    let coord = coordinator();
    let d = coord
        .deploy(&NetworkSpec::new("kws", PrecisionConfig::Mixed, 3))
        .unwrap();
    assert!(d
        .infer_scheduled(&op(), &[], Schedule::hybrid(8))
        .unwrap()
        .is_empty());
    let mut rng = Rng::new(54);
    let images: Vec<Vec<i32>> =
        (0..2).map(|_| d.random_input(&mut rng)).collect();
    // 0 threads degrades to 1 everywhere
    let got = d
        .infer_scheduled(&op(), &images, Schedule::auto(0))
        .unwrap();
    let want = d.infer_batch(&op(), &images, 1).unwrap();
    for (a, b) in got.iter().zip(&want) {
        assert_eq!(a.logits, b.logits, "0-thread schedule");
    }
}
