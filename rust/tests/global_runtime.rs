//! Process-wide work-stealing runtime acceptance (ISSUE 7): the
//! `Global` execution path must be bitwise identical to the `Owned`
//! scoped-pool A/B path across the full (batch, threads, mode) matrix
//! on both registry networks, concurrent tenants must share the one
//! runtime without interference, and repeated serving calls must
//! provision zero new threads (the telemetry that motivates the
//! refactor).

#![cfg(feature = "native")]

use marsellus::coordinator::{Coordinator, Schedule, ScheduleMode};
use marsellus::dnn::{NetworkSpec, PrecisionConfig};
use marsellus::power::OperatingPoint;
use marsellus::runtime::{global, ExecRuntime, Runtime};
use marsellus::util::Rng;

fn coordinator() -> Coordinator {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    let rt = Runtime::native(&dir).expect("native runtime");
    Coordinator::with_runtime(rt).expect("coordinator")
}

fn op() -> OperatingPoint {
    OperatingPoint::at_vdd(0.8)
}

const MODES: [ScheduleMode; 4] = [
    ScheduleMode::Auto,
    ScheduleMode::Batch,
    ScheduleMode::Latency,
    ScheduleMode::Hybrid,
];

/// Run the acceptance matrix on one deployed network: batches
/// {1, 3, 8, 17} x threads {1, 4, 16} x every mode, on **both**
/// runtimes, every cell bitwise equal to the single-threaded
/// sequential walk.
fn assert_owned_global_parity(net: &str, seed: u64, rng_seed: u64) {
    let coord = coordinator();
    let d = coord
        .deploy(&NetworkSpec::new(net, PrecisionConfig::Mixed, seed))
        .unwrap();
    let mut rng = Rng::new(rng_seed);
    for batch in [1usize, 3, 8, 17] {
        let images: Vec<Vec<i32>> =
            (0..batch).map(|_| d.random_input(&mut rng)).collect();
        // the 1-thread cell is the sequential walk on either runtime —
        // use it as the reference the whole matrix must match
        let want: Vec<Vec<i32>> = d
            .infer_scheduled_on(
                &op(),
                &images,
                Schedule::auto(1),
                ExecRuntime::Global,
            )
            .unwrap()
            .into_iter()
            .map(|r| r.logits)
            .collect();
        for threads in [1usize, 4, 16] {
            for mode in MODES {
                for rt in [ExecRuntime::Owned, ExecRuntime::Global] {
                    let got: Vec<Vec<i32>> = d
                        .infer_scheduled_on(
                            &op(),
                            &images,
                            Schedule { threads, mode },
                            rt,
                        )
                        .unwrap()
                        .into_iter()
                        .map(|r| r.logits)
                        .collect();
                    assert_eq!(
                        got, want,
                        "{net} batch {batch}, {threads} threads, {mode:?} \
                         on {rt:?} diverged from the sequential walk"
                    );
                }
            }
        }
    }
}

#[test]
fn kws_owned_vs_global_full_matrix() {
    assert_owned_global_parity("kws", 7, 60);
}

#[test]
fn resnet20_owned_vs_global_full_matrix() {
    assert_owned_global_parity("resnet20", 42, 61);
}

/// Multi-tenant serving: two deployments of different networks issue
/// overlapping `infer_scheduled` calls onto the one global runtime from
/// separate submitter threads. Every call must match that tenant's
/// sequential per-call reference bitwise, and no call may provision a
/// thread.
#[test]
fn concurrent_tenants_share_the_global_runtime() {
    let coord = coordinator();
    let kws = coord
        .deploy(&NetworkSpec::new("kws", PrecisionConfig::Mixed, 11))
        .unwrap();
    let resnet = coord
        .deploy(&NetworkSpec::new("resnet20", PrecisionConfig::Mixed, 12))
        .unwrap();
    let mut rng = Rng::new(62);
    let kws_images: Vec<Vec<i32>> =
        (0..6).map(|_| kws.random_input(&mut rng)).collect();
    let res_images: Vec<Vec<i32>> =
        (0..6).map(|_| resnet.random_input(&mut rng)).collect();
    // per-tenant references: sequential per-call path, no plan, 1 thread
    let kws_want: Vec<Vec<i32>> = kws
        .infer_batch_opts(&op(), &kws_images, 1, false)
        .unwrap()
        .into_iter()
        .map(|r| r.logits)
        .collect();
    let res_want: Vec<Vec<i32>> = resnet
        .infer_batch_opts(&op(), &res_images, 1, false)
        .unwrap()
        .into_iter()
        .map(|r| r.logits)
        .collect();
    // warm the runtime so its one-time worker spawn is behind us, then
    // pin the spawn counter across every overlapping call below
    kws.infer_scheduled_on(
        &op(),
        &kws_images[..1],
        Schedule::hybrid(4),
        ExecRuntime::Global,
    )
    .unwrap();
    let spawned_before = global().telemetry().spawned_threads;
    std::thread::scope(|s| {
        let submit = |d: &marsellus::coordinator::Deployment<'_>,
                      images: &[Vec<i32>],
                      want: &[Vec<i32>],
                      sched: Schedule,
                      tag: &str| {
            for round in 0..3 {
                let got: Vec<Vec<i32>> = d
                    .infer_scheduled_on(
                        &op(),
                        images,
                        sched,
                        ExecRuntime::Global,
                    )
                    .unwrap()
                    .into_iter()
                    .map(|r| r.logits)
                    .collect();
                assert_eq!(
                    got, want,
                    "{tag} round {round} diverged under concurrent serving"
                );
            }
        };
        s.spawn(|| {
            submit(&kws, &kws_images, &kws_want, Schedule::hybrid(4), "kws")
        });
        s.spawn(|| {
            submit(
                &resnet,
                &res_images,
                &res_want,
                Schedule::batch(4),
                "resnet20",
            )
        });
    });
    let after = global().telemetry();
    assert_eq!(
        after.spawned_threads, spawned_before,
        "overlapping serving calls provisioned threads: {after:?}"
    );
}

/// The provisioning telemetry the refactor exists for: after the first
/// warming call, repeated serving calls spawn **zero** new threads —
/// the worker fleet is a process-lifetime fixture, not a per-call cost.
#[test]
fn repeated_calls_spawn_no_threads() {
    let coord = coordinator();
    let d = coord
        .deploy(&NetworkSpec::new("kws", PrecisionConfig::Mixed, 13))
        .unwrap();
    let mut rng = Rng::new(63);
    let images: Vec<Vec<i32>> =
        (0..4).map(|_| d.random_input(&mut rng)).collect();
    // first call may lazily spawn the fleet
    d.infer_scheduled_on(
        &op(),
        &images,
        Schedule::batch(4),
        ExecRuntime::Global,
    )
    .unwrap();
    let spawned = global().telemetry().spawned_threads;
    let jobs_before = global().telemetry().jobs;
    for sched in [
        Schedule::batch(4),
        Schedule::latency(4),
        Schedule::hybrid(4),
        Schedule::auto(16),
    ] {
        d.infer_scheduled_on(&op(), &images, sched, ExecRuntime::Global)
            .unwrap();
        let t = global().telemetry();
        assert_eq!(
            t.spawned_threads, spawned,
            "{sched:?} spawned threads on a warm runtime: {t:?}"
        );
    }
    // the calls did stream jobs through the shared fleet (>= because
    // concurrently running tests may add their own)
    if global().width() > 1 {
        assert!(
            global().telemetry().jobs > jobs_before,
            "no jobs reached the global runtime"
        );
    }
}
