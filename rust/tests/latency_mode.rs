//! Single-image latency mode (`Deployment::infer_latency`): conv layers
//! tile-split across the worker pool must be bitwise identical to the
//! sequential `infer` walk at every worker count (ISSUE 4 acceptance
//! criterion), for unsigned and signed-head networks alike.

#![cfg(feature = "native")]

use marsellus::coordinator::Coordinator;
use marsellus::dnn::{NetworkSpec, PrecisionConfig};
use marsellus::power::OperatingPoint;
use marsellus::runtime::Runtime;
use marsellus::util::Rng;

fn coordinator() -> Coordinator {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    let rt = Runtime::native(&dir).expect("native runtime");
    Coordinator::with_runtime(rt).expect("coordinator")
}

fn op() -> OperatingPoint {
    OperatingPoint::at_vdd(0.8)
}

/// Latency mode vs sequential `infer`, bitwise, across 1/4/16 workers,
/// on both precision configs of ResNet-20 (the wide-word u64 plan path:
/// every non-stem layer has cin > 32).
#[test]
fn latency_mode_matches_sequential_infer_across_worker_counts() {
    let coord = coordinator();
    for config in [PrecisionConfig::Mixed, PrecisionConfig::Uniform8] {
        let spec = NetworkSpec::new("resnet20", config, 42);
        let d = coord.deploy(&spec).unwrap();
        let mut rng = Rng::new(31);
        for i in 0..2 {
            let image = d.random_input(&mut rng);
            let base = d.infer(&op(), &image).unwrap();
            for threads in [1usize, 4, 16] {
                let lat = d.infer_latency(&op(), &image, threads).unwrap();
                assert_eq!(
                    lat.logits, base.logits,
                    "{spec} image {i}: latency mode with {threads} \
                     workers diverged from sequential infer"
                );
            }
        }
    }
}

/// The signed-head KWS net serves through latency mode too: negative
/// logits survive tiling (the head itself is tiny and runs sequentially
/// under the MAC floor, the conv body tiles).
#[test]
fn signed_head_network_serves_in_latency_mode() {
    let coord = coordinator();
    let d = coord
        .deploy(&NetworkSpec::new("kws", PrecisionConfig::Mixed, 7))
        .unwrap();
    let mut rng = Rng::new(32);
    let mut saw_negative = false;
    for i in 0..6 {
        let image = d.random_input(&mut rng);
        let base = d.infer(&op(), &image).unwrap();
        saw_negative |= base.logits.iter().any(|&v| v < 0);
        for threads in [1usize, 4, 16] {
            let lat = d.infer_latency(&op(), &image, threads).unwrap();
            assert_eq!(
                lat.logits, base.logits,
                "image {i}, {threads} workers"
            );
        }
    }
    assert!(
        saw_negative,
        "no negative logit in 6 inputs — the signed head is not being \
         exercised"
    );
}

/// Latency mode and the batch worker pool agree image-for-image: the
/// two parallelism axes (tiles within one image, images across the
/// batch) are independently bitwise-exact.
#[test]
fn latency_mode_agrees_with_batch_pool() {
    let coord = coordinator();
    let d = coord
        .deploy(&NetworkSpec::new("resnet20", PrecisionConfig::Mixed, 3))
        .unwrap();
    let mut rng = Rng::new(33);
    let images: Vec<Vec<i32>> =
        (0..3).map(|_| d.random_input(&mut rng)).collect();
    let batch = d.infer_batch(&op(), &images, 4).unwrap();
    for (i, img) in images.iter().enumerate() {
        let lat = d.infer_latency(&op(), img, 4).unwrap();
        assert_eq!(lat.logits, batch[i].logits, "image {i}");
    }
}

/// Degenerate worker counts are serviced, not errors: 0 and 1 degrade
/// to the sequential walk.
#[test]
fn degenerate_worker_counts_degrade_to_sequential() {
    let coord = coordinator();
    let d = coord
        .deploy(&NetworkSpec::new("resnet20", PrecisionConfig::Mixed, 9))
        .unwrap();
    let mut rng = Rng::new(34);
    let image = d.random_input(&mut rng);
    let base = d.infer(&op(), &image).unwrap();
    for threads in [0usize, 1] {
        let lat = d.infer_latency(&op(), &image, threads).unwrap();
        assert_eq!(lat.logits, base.logits, "{threads} workers");
    }
}
