//! End-to-end integration: full ResNet-20 inference through the
//! coordinator (backend numerics + simulator timing), both precision
//! configurations.
//!
//! Runs against the native backend, so no `make artifacts` is needed —
//! the coordinator falls back to the built-in layer zoo. Streams
//! through `Coordinator::deploy` handles, the one serving surface (the
//! PR-3 wrapper shims are gone); `tests/deploy_api.rs` covers the
//! handle lifecycle itself.

#![cfg(feature = "native")]

use marsellus::coordinator::{random_image, Coordinator};
use marsellus::dnn::{NetworkSpec, PrecisionConfig};
use marsellus::power::{OperatingPoint, FBB_MAX_V};
use marsellus::runtime::Runtime;
use marsellus::util::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn coordinator() -> Coordinator {
    // Explicitly native: e2e behaviour must not depend on the caller's
    // MARSELLUS_BACKEND environment.
    let rt = Runtime::native(&artifacts_dir()).expect("native runtime");
    Coordinator::with_runtime(rt).expect("coordinator")
}

fn spec(config: PrecisionConfig, seed: u64) -> NetworkSpec {
    NetworkSpec::new("resnet20", config, seed)
}

#[test]
fn inference_runs_and_is_deterministic() {
    let coord = coordinator();
    let mut rng = Rng::new(1);
    let image = random_image(8, &mut rng);
    let op = OperatingPoint::at_vdd(0.8);
    for config in [PrecisionConfig::Uniform8, PrecisionConfig::Mixed] {
        let d = coord.deploy(&spec(config, 42)).unwrap();
        let a = d.infer(&op, &image).unwrap();
        let b = d.infer(&op, &image).unwrap();
        assert_eq!(a.logits, b.logits, "{config:?} determinism");
        assert_eq!(a.logits.len(), 10);
        // O-bit output range of the fc layer
        let omax = 1 << 8;
        assert!(a.logits.iter().all(|&v| v >= 0 && v < omax));
    }
}

#[test]
fn different_weights_give_different_logits() {
    let coord = coordinator();
    let image = random_image(8, &mut Rng::new(2));
    let op = OperatingPoint::at_vdd(0.8);
    let a = coord
        .deploy(&spec(PrecisionConfig::Mixed, 1))
        .unwrap()
        .infer(&op, &image)
        .unwrap();
    let b = coord
        .deploy(&spec(PrecisionConfig::Mixed, 2))
        .unwrap()
        .infer(&op, &image)
        .unwrap();
    assert_ne!(a.logits, b.logits);
}

/// The in-flight cross-check: backend outputs equal the Rust bit-serial
/// datapath on representative layers (small stage-3 + strided 1x1).
#[test]
fn backend_vs_bitserial_cross_check() {
    let coord = coordinator();
    let image = random_image(8, &mut Rng::new(3));
    let res = coord
        .deploy(&spec(PrecisionConfig::Mixed, 7))
        .unwrap()
        .infer_cross_checked(
            &OperatingPoint::at_vdd(0.8),
            &image,
            &["stage3.b1.conv0", "stage3.b2.conv1"],
        )
        .unwrap();
    assert_eq!(res.cross_checked, 2);
}

/// Timing/energy reports behave physically across operating points.
#[test]
fn operating_point_scaling() {
    let coord = coordinator();
    let image = random_image(8, &mut Rng::new(4));
    let d = coord.deploy(&spec(PrecisionConfig::Mixed, 42)).unwrap();
    let nominal = d.infer(&OperatingPoint::at_vdd(0.8), &image).unwrap();
    let low = d.infer(&OperatingPoint::at_vdd(0.5), &image).unwrap();
    let abb = d
        .infer(
            &OperatingPoint { vdd: 0.65, freq_mhz: 400.0, fbb_v: FBB_MAX_V },
            &image,
        )
        .unwrap();
    // same functional result regardless of operating point
    assert_eq!(nominal.logits, low.logits);
    assert_eq!(nominal.logits, abb.logits);
    // 0.5 V: slower but more efficient
    assert!(low.report.total_latency_us()
            > 2.0 * nominal.report.total_latency_us());
    assert!(low.report.total_energy_uj()
            < nominal.report.total_energy_uj());
    // 0.65 V + ABB: no performance penalty vs 400 MHz-equivalent, less
    // energy than nominal (paper §IV)
    assert!(abb.report.total_energy_uj()
            < nominal.report.total_energy_uj());
    assert!(abb.report.total_latency_us()
            < 1.2 * nominal.report.total_latency_us());
}

/// PJRT-era regression guard: when AOT artifacts *are* on disk, the
/// manifest they ship must agree with the AOT subset of the built-in
/// zoo (`Manifest::aot_zoo` — exactly what `aot.py` lowers; the other
/// registry networks are Rust-builtin only and have no python mirror).
/// Skips cleanly when `make artifacts` has not run.
#[test]
fn on_disk_artifacts_match_aot_zoo() {
    let dir = artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("SKIP: artifacts missing; run `make artifacts`");
        return;
    }
    let rt = Runtime::native(&dir).expect("native runtime");
    let aot = marsellus::dnn::Manifest::aot_zoo();
    let disk = marsellus::dnn::Manifest::load(&dir).unwrap();
    for name in aot.names() {
        // aot.py writes a row for every python-lowered spec: a missing
        // row means the python and rust layer zoos have drifted apart
        let d = disk
            .get(&name)
            .unwrap_or_else(|| panic!("disk manifest has no row for {name}"));
        assert_eq!(d, aot.get(&name).unwrap(), "signature drift for {name}");
        if !rt.artifact_file_exists(&name) {
            eprintln!("SKIP: {name}.hlo.txt not on disk (partial build)");
        }
    }
}
