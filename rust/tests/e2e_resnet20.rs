//! End-to-end integration: full ResNet-20 inference through the
//! coordinator (PJRT numerics + simulator timing), both precision
//! configurations. Skips when artifacts are missing.

use marsellus::coordinator::{random_image, Coordinator};
use marsellus::dnn::PrecisionConfig;
use marsellus::power::{OperatingPoint, FBB_MAX_V};
use marsellus::util::Rng;

fn coordinator() -> Option<Coordinator> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    if !dir.join("manifest.tsv").exists() {
        eprintln!("SKIP: artifacts missing; run `make artifacts`");
        return None;
    }
    Some(Coordinator::new(dir.to_str().unwrap()).expect("coordinator"))
}

#[test]
fn inference_runs_and_is_deterministic() {
    let Some(coord) = coordinator() else { return };
    let mut rng = Rng::new(1);
    let image = random_image(8, &mut rng);
    let op = OperatingPoint::at_vdd(0.8);
    for config in [PrecisionConfig::Uniform8, PrecisionConfig::Mixed] {
        let a = coord
            .infer_resnet20(config, &op, &image, 42, &[])
            .unwrap();
        let b = coord
            .infer_resnet20(config, &op, &image, 42, &[])
            .unwrap();
        assert_eq!(a.logits, b.logits, "{config:?} determinism");
        assert_eq!(a.logits.len(), 10);
        // O-bit output range of the fc layer
        let omax = 1 << 8;
        assert!(a.logits.iter().all(|&v| v >= 0 && v < omax));
    }
}

#[test]
fn different_weights_give_different_logits() {
    let Some(coord) = coordinator() else { return };
    let image = random_image(8, &mut Rng::new(2));
    let op = OperatingPoint::at_vdd(0.8);
    let a = coord
        .infer_resnet20(PrecisionConfig::Mixed, &op, &image, 1, &[])
        .unwrap();
    let b = coord
        .infer_resnet20(PrecisionConfig::Mixed, &op, &image, 2, &[])
        .unwrap();
    assert_ne!(a.logits, b.logits);
}

/// The in-flight cross-check: artifact outputs equal the Rust bit-serial
/// datapath on representative layers (small stage-3 + strided 1x1).
#[test]
fn artifact_vs_bitserial_cross_check() {
    let Some(coord) = coordinator() else { return };
    let image = random_image(8, &mut Rng::new(3));
    let res = coord
        .infer_resnet20(
            PrecisionConfig::Mixed,
            &OperatingPoint::at_vdd(0.8),
            &image,
            7,
            &["stage3.b1.conv0", "stage3.b2.conv1"],
        )
        .unwrap();
    assert_eq!(res.cross_checked, 2);
}

/// Timing/energy reports behave physically across operating points.
#[test]
fn operating_point_scaling() {
    let Some(coord) = coordinator() else { return };
    let image = random_image(8, &mut Rng::new(4));
    let nominal = coord
        .infer_resnet20(
            PrecisionConfig::Mixed,
            &OperatingPoint::at_vdd(0.8),
            &image,
            42,
            &[],
        )
        .unwrap();
    let low = coord
        .infer_resnet20(
            PrecisionConfig::Mixed,
            &OperatingPoint::at_vdd(0.5),
            &image,
            42,
            &[],
        )
        .unwrap();
    let abb = coord
        .infer_resnet20(
            PrecisionConfig::Mixed,
            &OperatingPoint { vdd: 0.65, freq_mhz: 400.0, fbb_v: FBB_MAX_V },
            &image,
            42,
            &[],
        )
        .unwrap();
    // same functional result regardless of operating point
    assert_eq!(nominal.logits, low.logits);
    assert_eq!(nominal.logits, abb.logits);
    // 0.5 V: slower but more efficient
    assert!(low.report.total_latency_us()
            > 2.0 * nominal.report.total_latency_us());
    assert!(low.report.total_energy_uj()
            < nominal.report.total_energy_uj());
    // 0.65 V + ABB: no performance penalty vs 400 MHz-equivalent, less
    // energy than nominal (paper §IV)
    assert!(abb.report.total_energy_uj()
            < nominal.report.total_energy_uj());
    assert!(abb.report.total_latency_us()
            < 1.2 * nominal.report.total_latency_us());
}
