//! Plan-cache eviction: fill the bounded multi-tenant cache past its
//! byte budget with distinct `NetworkSpec` deployments and assert LRU
//! victims, byte accounting, and that a re-deployed evictee rebuilds
//! bit-identically (ISSUE 3 satellite).

#![cfg(feature = "native")]

use marsellus::coordinator::Coordinator;
use marsellus::dnn::{NetworkSpec, PrecisionConfig};
use marsellus::power::OperatingPoint;
use marsellus::runtime::Runtime;
use marsellus::util::Rng;

fn coordinator() -> Coordinator {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    let rt = Runtime::native(&dir).expect("native runtime");
    Coordinator::with_runtime(rt).expect("coordinator")
}

fn kws(seed: u64) -> NetworkSpec {
    NetworkSpec::new("kws", PrecisionConfig::Mixed, seed)
}

fn op() -> OperatingPoint {
    OperatingPoint::at_vdd(0.8)
}

/// LRU eviction under a byte budget sized for two tenants: the
/// least-recently-*used* deployment is the victim (not the
/// least-recently-built one), bytes are accounted down on eviction, and
/// the resident set stays under budget.
#[test]
fn lru_eviction_respects_budget_and_recency() {
    let coord = coordinator();
    let rt = &coord.runtime;

    // measure one tenant's plan footprint, then budget for two
    coord.deploy(&kws(1)).unwrap();
    let one = rt.plan_bytes();
    assert!(one > 0, "plans must account bytes");
    assert_eq!(rt.cached_plans(), 1);
    let budget = 2 * one + one / 2;
    rt.set_plan_cache_budget(budget);

    coord.deploy(&kws(2)).unwrap();
    assert_eq!(rt.cached_plans(), 2);
    assert_eq!(rt.plan_evictions(), 0);
    assert_eq!(rt.plan_bytes(), 2 * one, "two identical-shape tenants");

    // touch tenant 1 so tenant 2 becomes the LRU victim
    coord.deploy(&kws(1)).unwrap();
    assert_eq!(rt.plan_builds(), 2, "touching must not rebuild");

    coord.deploy(&kws(3)).unwrap();
    assert_eq!(rt.plan_evictions(), 1, "third tenant exceeds the budget");
    assert_eq!(rt.cached_plans(), 2);
    assert!(rt.plan_bytes() <= budget, "{} > {budget}", rt.plan_bytes());
    let resident: Vec<u64> = rt
        .cached_plan_specs()
        .into_iter()
        .map(|s| s.seed)
        .collect();
    assert!(resident.contains(&1), "recently-used tenant evicted");
    assert!(resident.contains(&3), "fresh tenant evicted");
    assert!(!resident.contains(&2), "LRU tenant survived");
}

/// A re-deployed evictee rebuilds bit-identically: eviction is a pure
/// memory policy, never a numerics event.
#[test]
fn evicted_deployment_rebuilds_bit_identically() {
    let coord = coordinator();
    let rt = &coord.runtime;
    let mut rng = Rng::new(40);

    let d1 = coord.deploy(&kws(1)).unwrap();
    let inputs: Vec<Vec<i32>> =
        (0..3).map(|_| d1.random_input(&mut rng)).collect();
    let before: Vec<Vec<i32>> = d1
        .infer_batch(&op(), &inputs, 2)
        .unwrap()
        .into_iter()
        .map(|r| r.logits)
        .collect();

    // budget for one tenant only: deploying tenant 2 evicts tenant 1
    rt.set_plan_cache_budget(rt.plan_bytes() + 1);
    coord.deploy(&kws(2)).unwrap();
    assert_eq!(rt.plan_evictions(), 1);
    assert!(!rt.cached_plan_specs().iter().any(|s| s.seed == 1));

    // re-deploy the evictee: fresh compile, identical logits
    let builds = rt.plan_builds();
    let d1_again = coord.deploy(&kws(1)).unwrap();
    assert_eq!(rt.plan_builds(), builds + 1, "evictee must recompile");
    let after: Vec<Vec<i32>> = d1_again
        .infer_batch(&op(), &inputs, 2)
        .unwrap()
        .into_iter()
        .map(|r| r.logits)
        .collect();
    assert_eq!(before, after, "rebuilt plan changed the numerics");
}

/// A single deployment larger than the whole budget is kept resident:
/// the bound sheds *other* tenants, it never refuses to serve the one
/// active deployment.
#[test]
fn oversize_single_tenant_is_still_served() {
    let coord = coordinator();
    let rt = &coord.runtime;
    rt.set_plan_cache_budget(1);

    let d = coord.deploy(&kws(9)).unwrap();
    assert_eq!(rt.cached_plans(), 1);
    assert_eq!(rt.plan_evictions(), 0, "sole resident must not be evicted");
    assert!(rt.plan_bytes() > rt.plan_cache_budget());
    let mut rng = Rng::new(41);
    let input = d.random_input(&mut rng);
    assert_eq!(d.infer(&op(), &input).unwrap().logits.len(), 12);

    // a second tenant displaces the first immediately (LRU), keeping
    // exactly one resident
    coord.deploy(&kws(10)).unwrap();
    assert_eq!(rt.cached_plans(), 1);
    assert_eq!(rt.plan_evictions(), 1);
    assert_eq!(rt.cached_plan_specs()[0].seed, 10);
}

/// Multi-tenant churn: many distinct deployments stream through a
/// two-tenant budget; the cache never exceeds it (after the sweep) and
/// every tenant still serves correct logits on arrival.
#[test]
fn many_tenants_stay_under_the_bound() {
    let coord = coordinator();
    let rt = &coord.runtime;
    coord.deploy(&kws(0)).unwrap();
    let one = rt.plan_bytes();
    let budget = 2 * one + one / 2;
    rt.set_plan_cache_budget(budget);

    let mut rng = Rng::new(42);
    for seed in 1..=8u64 {
        let d = coord.deploy(&kws(seed)).unwrap();
        let input = d.random_input(&mut rng);
        assert_eq!(d.infer(&op(), &input).unwrap().logits.len(), 12);
        assert!(
            rt.plan_bytes() <= budget,
            "seed {seed}: {} resident > {budget} budget",
            rt.plan_bytes()
        );
        assert!(rt.cached_plans() <= 2);
    }
    assert_eq!(rt.plan_builds(), 9);
    assert_eq!(rt.plan_evictions(), 7);
}

/// A pinned plan is never the LRU victim: under budget pressure the
/// sweep takes the oldest *unpinned* resident instead, even when the
/// pinned plan is the least recently used. Unpinning restores
/// evictability.
#[test]
fn pinned_plan_survives_cache_pressure() {
    let coord = coordinator();
    let rt = &coord.runtime;

    coord.deploy(&kws(1)).unwrap();
    let one = rt.plan_bytes();
    rt.set_plan_cache_budget(2 * one + one / 2);
    rt.pin_plan(&kws(1)).expect("resident plan pins");
    assert_eq!(rt.pinned_plan_bytes(), one);
    assert_eq!(rt.pinned_plan_specs(), vec![kws(1)]);

    // tenants 2 and 3: tenant 1 is the LRU, but pinned — tenant 2
    // (oldest unpinned) must be the victim instead
    coord.deploy(&kws(2)).unwrap();
    coord.deploy(&kws(3)).unwrap();
    assert_eq!(rt.plan_evictions(), 1);
    let resident: Vec<u64> =
        rt.cached_plan_specs().into_iter().map(|s| s.seed).collect();
    assert!(resident.contains(&1), "pinned LRU plan was evicted");
    assert!(resident.contains(&3), "fresh tenant evicted");
    assert!(!resident.contains(&2), "oldest unpinned tenant survived");

    // unpin: tenant 1 becomes the ordinary LRU victim again
    assert!(rt.unpin_plan(&kws(1)), "pin was set");
    assert!(!rt.unpin_plan(&kws(1)), "second unpin is a no-op");
    coord.deploy(&kws(4)).unwrap();
    assert_eq!(rt.plan_evictions(), 2);
    assert!(
        !rt.cached_plan_specs().iter().any(|s| s.seed == 1),
        "unpinned plan must be evictable again"
    );
}

/// Pinning fails loudly when the pinned set alone would exceed the
/// cache budget, and when the spec has no resident plan; a failed pin
/// changes nothing.
#[test]
fn over_budget_and_non_resident_pins_fail_loudly() {
    let coord = coordinator();
    let rt = &coord.runtime;

    let err = rt.pin_plan(&kws(1)).expect_err("nothing resident yet");
    assert!(
        format!("{err:#}").contains("deploy it first"),
        "got: {err:#}"
    );

    coord.deploy(&kws(1)).unwrap();
    let one = rt.plan_bytes();
    coord.deploy(&kws(2)).unwrap();
    rt.set_plan_cache_budget(one + one / 2);
    rt.pin_plan(&kws(1)).expect("first pin fits the budget");
    rt.pin_plan(&kws(1)).expect("re-pinning is idempotent");
    let err = rt
        .pin_plan(&kws(2))
        .expect_err("two pins cannot fit a 1.5-plan budget");
    let msg = format!("{err:#}");
    assert!(msg.contains("exceeding"), "got: {msg}");
    assert!(msg.contains("MARSELLUS_PLAN_CACHE_BYTES"), "got: {msg}");
    assert_eq!(rt.pinned_plan_bytes(), one, "failed pin must not stick");

    // an all-pinned cache over budget stays over budget rather than
    // breaking the residency guarantee
    assert_eq!(rt.cached_plans(), 2);
    assert!(rt.plan_bytes() > rt.plan_cache_budget());
}

/// The per-deployment residency split: rows carry bytes + pin state,
/// sum to the cache total, and `plan_bytes_of` reads one tenant's
/// share.
#[test]
fn residency_rows_sum_to_the_cache_total() {
    let coord = coordinator();
    let rt = &coord.runtime;
    coord.deploy(&kws(1)).unwrap();
    coord.deploy(&kws(2)).unwrap();
    rt.pin_plan(&kws(2)).unwrap();

    let rows = rt.plan_residency();
    assert_eq!(rows.len(), 2);
    assert_eq!(
        rows.iter().map(|r| r.bytes).sum::<usize>(),
        rt.plan_bytes(),
        "residency rows must sum to plan_bytes"
    );
    for r in &rows {
        assert_eq!(r.pinned, r.spec.seed == 2, "{}", r.spec);
        assert_eq!(rt.plan_bytes_of(&r.spec), Some(r.bytes));
    }
    assert_eq!(rt.plan_bytes_of(&kws(99)), None, "not resident");
}
