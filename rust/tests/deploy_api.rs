//! Deployment-API acceptance: every registry network — including
//! ResNet-18 (previously schedule-report-only) and the signed-head KWS
//! net — serves end-to-end through `Coordinator::deploy` →
//! `Deployment::{infer, infer_batch, profile}`, bitwise identical
//! across batch sizes and 1/4/16 worker threads, and bitwise
//! reproducible across coordinator instances.

#![cfg(feature = "native")]

use marsellus::coordinator::Coordinator;
use marsellus::dnn::{NetworkSpec, PrecisionConfig};
use marsellus::power::OperatingPoint;
use marsellus::runtime::Runtime;
use marsellus::util::Rng;

fn coordinator() -> Coordinator {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    let rt = Runtime::native(&dir).expect("native runtime");
    Coordinator::with_runtime(rt).expect("coordinator")
}

fn op() -> OperatingPoint {
    OperatingPoint::at_vdd(0.8)
}

/// The signed-head KWS network end to end, both configs: logits stay in
/// the signed 8-bit range, go negative (ReLU would forbid that — this
/// is `NormQuant::apply_signed` exercised through a served network),
/// and the plan path matches the per-call path bit for bit.
#[test]
fn kws_signed_head_serves_end_to_end() {
    let coord = coordinator();
    for config in [PrecisionConfig::Uniform8, PrecisionConfig::Mixed] {
        let spec = NetworkSpec::new("kws", config, 77);
        let d = coord.deploy(&spec).unwrap();
        assert_eq!(d.input_dims(), (16, 8));
        let mut rng = Rng::new(20);
        let inputs: Vec<Vec<i32>> =
            (0..6).map(|_| d.random_input(&mut rng)).collect();

        let planned = d.infer_batch(&op(), &inputs, 1).unwrap();
        let per_call = d.infer_batch_opts(&op(), &inputs, 1, false).unwrap();
        let mut saw_negative = false;
        for (i, (a, b)) in planned.iter().zip(&per_call).enumerate() {
            assert_eq!(
                a.logits, b.logits,
                "{config:?} input {i}: plan vs per-call"
            );
            assert_eq!(a.logits.len(), 12);
            assert!(a.logits.iter().all(|&v| (-128..=127).contains(&v)));
            saw_negative |= a.logits.iter().any(|&v| v < 0);
        }
        assert!(
            saw_negative,
            "{config:?}: no negative logit in {} inputs — the signed \
             head is not being exercised",
            inputs.len()
        );
        // bitwise identical across 1/4/16 worker threads
        for threads in [4usize, 16] {
            let got = d.infer_batch(&op(), &inputs, threads).unwrap();
            for (a, b) in planned.iter().zip(&got) {
                assert_eq!(a.logits, b.logits, "{config:?} {threads} threads");
            }
        }
        // profile covers every layer, head included
        let split = d.profile(&inputs[0]).unwrap();
        assert_eq!(split.len(), d.layers().len());
        assert!(split.iter().any(|l| l.name == "head"));
    }
}

/// ResNet-18 goes from schedule-report-only to fully served: deployed
/// through the same handle API as ResNet-20, 1000 logits, bitwise
/// identical across batch sizes and 1/4/16 worker threads, and the
/// plan path equals the per-call backend path.
#[test]
fn resnet18_serves_end_to_end() {
    let coord = coordinator();
    let spec = NetworkSpec::new("resnet18", PrecisionConfig::Mixed, 42);
    let d = coord.deploy(&spec).unwrap();
    assert_eq!(d.input_dims(), (224, 17));
    assert_eq!(d.input_bits(), 4);
    let mut rng = Rng::new(21);
    let images: Vec<Vec<i32>> =
        (0..2).map(|_| d.random_input(&mut rng)).collect();

    let base = d.infer_batch(&op(), &images, 1).unwrap();
    assert_eq!(base.len(), 2);
    for r in &base {
        assert_eq!(r.logits.len(), 1000);
        assert!(r.logits.iter().all(|&v| (0..256).contains(&v)));
    }
    assert_ne!(base[0].logits, base[1].logits, "degenerate forward");

    // batch-size independence: solo infer equals the batch member
    let solo = d.infer(&op(), &images[0]).unwrap();
    assert_eq!(solo.logits, base[0].logits, "batch=1 vs batch=2");

    // thread-count independence across the acceptance matrix
    for threads in [4usize, 16] {
        let got = d.infer_batch(&op(), &images, threads).unwrap();
        for (i, (a, b)) in base.iter().zip(&got).enumerate() {
            assert_eq!(a.logits, b.logits, "image {i}, {threads} threads");
        }
    }

    // the precompiled plan equals per-call backend execution bit for bit
    let per_call =
        d.infer_batch_opts(&op(), &images[..1], 1, false).unwrap();
    assert_eq!(per_call[0].logits, base[0].logits, "plan vs per-call");

    // the timing report is the familiar Table II magnitude (~tens of ms
    // at 0.5 V; at 0.8 V just assert it is far heavier than ResNet-20)
    let r20 = coord
        .deploy(&NetworkSpec::new("resnet20", PrecisionConfig::Mixed, 42))
        .unwrap();
    let rep18 = d.report(&op()).unwrap();
    let rep20 = r20.report(&op()).unwrap();
    assert!(
        rep18.total_latency_us() > 10.0 * rep20.total_latency_us(),
        "{} vs {}",
        rep18.total_latency_us(),
        rep20.total_latency_us()
    );
}

/// Deployments are bitwise reproducible across coordinator instances:
/// the spec alone determines the weights, the plan, and the logits.
#[test]
fn deployments_reproduce_across_coordinators() {
    let spec = NetworkSpec::new("kws", PrecisionConfig::Mixed, 5);
    let mut rng = Rng::new(30);
    let input = {
        let coord = coordinator();
        coord.deploy(&spec).unwrap().random_input(&mut rng)
    };
    let mut logits = Vec::new();
    for _ in 0..2 {
        let coord = coordinator();
        let d = coord.deploy(&spec).unwrap();
        logits.push(d.infer(&op(), &input).unwrap().logits);
    }
    assert_eq!(logits[0], logits[1]);
}

/// Cross-check layer names must match a conv layer: a typo (or a
/// non-conv layer) errors instead of silently verifying nothing.
#[test]
fn cross_check_validates_layer_names() {
    let coord = coordinator();
    let d = coord
        .deploy(&NetworkSpec::new("kws", PrecisionConfig::Mixed, 2))
        .unwrap();
    let mut rng = Rng::new(33);
    let input = d.random_input(&mut rng);
    // valid conv layer: runs and really checks it
    let ok = d.infer_cross_checked(&op(), &input, &["stem"]).unwrap();
    assert_eq!(ok.cross_checked, 1);
    // typo and non-conv head both fail loudly, naming the candidates
    for bad in ["stemm", "head"] {
        let err = d
            .infer_cross_checked(&op(), &input, &[bad])
            .unwrap_err()
            .to_string();
        assert!(err.contains(bad), "{err}");
        assert!(err.contains("stem") && err.contains("body"), "{err}");
    }
}

/// Spec resolution fails loudly: unknown ids name the known registry.
#[test]
fn unknown_network_is_a_clean_error() {
    let coord = coordinator();
    let err = coord
        .deploy(&NetworkSpec::new("resnet50", PrecisionConfig::Mixed, 0))
        .unwrap_err()
        .to_string();
    assert!(err.contains("resnet50"), "{err}");
    assert!(err.contains("resnet20") && err.contains("kws"), "{err}");
}

/// The scheduler report is memoized per operating point but correct
/// across op changes.
#[test]
fn report_memo_tracks_operating_point() {
    let coord = coordinator();
    let d = coord
        .deploy(&NetworkSpec::new("kws", PrecisionConfig::Uniform8, 1))
        .unwrap();
    let nominal = d.report(&OperatingPoint::at_vdd(0.8)).unwrap();
    let again = d.report(&OperatingPoint::at_vdd(0.8)).unwrap();
    assert!(std::sync::Arc::ptr_eq(&nominal, &again), "memo not reused");
    let low = d.report(&OperatingPoint::at_vdd(0.5)).unwrap();
    assert!(low.total_latency_us() > nominal.total_latency_us());
    assert!(low.total_energy_uj() < nominal.total_energy_uj());
}
