//! Benchmark harness (`cargo bench`): regenerates every table and figure
//! of the paper's evaluation end-to-end and reports wall-clock cost of
//! each reproduction plus the headline measured numbers.
//!
//! criterion is not vendored in this build environment, so this is a
//! self-contained harness (`harness = false`): each benchmark runs the
//! full generator (ISS execution, RBE/power models, ABB co-simulation),
//! timed over several iterations with a minimum-of-N policy.
//!
//! Flags (after `--`):
//!   --smoke | --quick   cheap subset, 1 iteration each — the CI mode
//!   --json PATH         also write machine-readable results (CI uploads
//!                       BENCH_ci.json to record the perf trajectory)
//!
//! Besides the figure reproductions, the harness measures serving
//! throughput of `Coordinator::infer_batch` (pre-plan per-call path vs
//! the precompiled LayerPlan path, sequential and parallel) and
//! single-image latency (`Deployment::infer` vs the tile-parallel
//! `infer_latency` mode), recording images/s, per-image milliseconds
//! and the per-layer setup-vs-compute split into the JSON —
//! `ci/check_bench.py` gates both the throughput and the latency
//! sections against the committed baseline. The `tuned` section
//! re-deploys with the deploy-time autotuner and pins
//! `tuned_vs_heuristic >= 1.0`: a tuned configuration may never lose
//! to the fixed heuristics it replaced. The `global` section serves the
//! same batch through a per-call `Owned` pool and the process-wide
//! work-stealing runtime and pins `reuse_vs_provision >= 1.0`: reusing
//! the standing worker fleet may never lose to provisioning one per
//! call; it also measures two tenants submitting concurrently.

use std::time::Instant;

struct BenchResult {
    id: &'static str,
    best_ms: f64,
    iters: u32,
    headline: String,
}

fn bench(id: &'static str, iters: u32) -> BenchResult {
    let mut best = f64::INFINITY;
    let mut out = String::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        out = marsellus::figures::generate(id, false)
            .unwrap_or_else(|e| panic!("{id}: {e}"));
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    // headline: first data row after the table rule
    let headline = out
        .lines()
        .skip_while(|l| !l.starts_with('-'))
        .nth(1)
        .unwrap_or("")
        .trim()
        .to_string();
    BenchResult { id, best_ms: best, iters, headline }
}

fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Cargo runs bench binaries with cwd = the package root (`rust/`);
/// resolve relative `--json` paths against the workspace root so
/// `cargo bench -- --json BENCH_ci.json` lands where CI expects it.
fn resolve_out_path(path: &str) -> std::path::PathBuf {
    let p = std::path::Path::new(path);
    if p.is_absolute() {
        return p.to_path_buf();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join(p)
}

/// Serving-throughput measurements of the three `infer_batch` modes.
struct Throughput {
    images: usize,
    threads: usize,
    per_call_img_s: f64,
    planned_img_s: f64,
    parallel_img_s: f64,
    layers: Vec<marsellus::metrics::LayerSplit>,
}

impl Throughput {
    fn speedup_planned(&self) -> f64 {
        self.planned_img_s / self.per_call_img_s
    }

    fn speedup_parallel(&self) -> f64 {
        self.parallel_img_s / self.per_call_img_s
    }

    fn to_json(&self) -> String {
        let layers: Vec<String> = self
            .layers
            .iter()
            .map(|l| {
                format!(
                    "   {{\"name\": \"{}\", \"setup_us\": {:.1}, \
                     \"pack_us\": {:.1}, \"compute_us\": {:.1}}}",
                    json_escape(&l.name),
                    l.setup_us,
                    l.pack_us,
                    l.compute_us
                )
            })
            .collect();
        let (setup, compute) = self.layers.iter().fold((0.0, 0.0), |(s, c), l| {
            (s + l.setup_us, c + l.compute_us)
        });
        format!(
            " {{\n  \"images\": {},\n  \"threads\": {},\n  \
             \"per_call_img_s\": {:.3},\n  \"planned_img_s\": {:.3},\n  \
             \"parallel_img_s\": {:.3},\n  \"speedup_planned\": {:.3},\n  \
             \"speedup_parallel\": {:.3},\n  \"setup_us_total\": {:.1},\n  \
             \"compute_us_total\": {:.1},\n  \"layers\": [\n{}\n  ]\n }}",
            self.images,
            self.threads,
            self.per_call_img_s,
            self.planned_img_s,
            self.parallel_img_s,
            self.speedup_planned(),
            self.speedup_parallel(),
            setup,
            compute,
            layers.join(",\n")
        )
    }
}

/// Measure `infer_batch` images/s on the ResNet-20 example: the pre-plan
/// per-call path (sequential), the LayerPlan path (sequential), and the
/// LayerPlan path over the intra-batch worker pool — asserting along the
/// way that all three produce bitwise-identical logits.
fn throughput_bench(smoke: bool) -> Throughput {
    use marsellus::coordinator::Coordinator;
    use marsellus::dnn::{NetworkSpec, PrecisionConfig};
    use marsellus::power::OperatingPoint;
    use marsellus::util::Rng;

    let dir = marsellus::runtime::Runtime::resolve_artifacts_dir(None);
    let coord = Coordinator::new(dir).expect("coordinator");
    let spec = NetworkSpec::new("resnet20", PrecisionConfig::Mixed, 42);
    let op = OperatingPoint::at_vdd(0.8);
    let n = if smoke { 8 } else { 24 };
    let threads = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(4);
    // Deploy untimed: the one-time plan compilation is the *setup* half
    // of the split (reported per layer below), and must not be charged
    // to the per-image serving throughput the CI gate checks.
    let deployment = coord.deploy(&spec).expect("deploy");
    let mut rng = Rng::new(0xBE7C);
    let images: Vec<Vec<i32>> =
        (0..n).map(|_| deployment.random_input(&mut rng)).collect();

    let run = |use_plans: bool, threads: usize| {
        let t0 = Instant::now();
        let res = deployment
            .infer_batch_opts(&op, &images, threads, use_plans)
            .expect("infer_batch");
        let img_s = n as f64 / t0.elapsed().as_secs_f64();
        let logits: Vec<Vec<i32>> =
            res.into_iter().map(|r| r.logits).collect();
        (img_s, logits)
    };
    let (per_call_img_s, base) = run(false, 1);
    let (planned_img_s, planned) = run(true, 1);
    let (parallel_img_s, parallel) = run(true, threads);
    assert_eq!(base, planned, "plan path changed logits");
    assert_eq!(base, parallel, "parallel path changed logits");

    let layers = deployment.profile(&images[0]).expect("profile");
    Throughput {
        images: n,
        threads,
        per_call_img_s,
        planned_img_s,
        parallel_img_s,
        layers,
    }
}

/// Single-image latency measurements: the sequential plan walk vs the
/// **legacy** spawn-per-layer tiler (`ConvPlan::run_tiled` via
/// `infer_latency_opts(.., pooled: false)`), best-of-N per mode. The
/// persistent-pool path is measured separately by the `hybrid` section
/// so `speedup_tile` keeps its ISSUE-4 meaning and `speedup_pool` can
/// be gated against it.
struct Latency {
    threads: usize,
    iters: u32,
    seq_ms: f64,
    tile_ms: f64,
}

impl Latency {
    /// Machine-independent ratio the CI gate pins: how much faster one
    /// image finishes with conv tiles split across the pool.
    fn speedup_tile(&self) -> f64 {
        self.seq_ms / self.tile_ms
    }

    fn to_json(&self) -> String {
        format!(
            " {{\n  \"threads\": {},\n  \"iters\": {},\n  \
             \"seq_ms\": {:.3},\n  \"tile_ms\": {:.3},\n  \
             \"speedup_tile\": {:.3}\n }}",
            self.threads,
            self.iters,
            self.seq_ms,
            self.tile_ms,
            self.speedup_tile()
        )
    }
}

/// Measure single-image latency on the ResNet-20 example: sequential
/// `infer` vs tile-parallel `infer_latency` on the same deployment,
/// asserting bitwise-identical logits along the way.
fn latency_bench(smoke: bool) -> Latency {
    use marsellus::coordinator::Coordinator;
    use marsellus::dnn::{NetworkSpec, PrecisionConfig};
    use marsellus::power::OperatingPoint;
    use marsellus::util::Rng;

    let dir = marsellus::runtime::Runtime::resolve_artifacts_dir(None);
    let coord = Coordinator::new(dir).expect("coordinator");
    let spec = NetworkSpec::new("resnet20", PrecisionConfig::Mixed, 42);
    let op = OperatingPoint::at_vdd(0.8);
    let threads = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(4);
    let iters = if smoke { 5 } else { 15 };
    let deployment = coord.deploy(&spec).expect("deploy");
    let mut rng = Rng::new(0x1A7E);
    let image = deployment.random_input(&mut rng);
    // warm both paths (memoizes the scheduler report, faults pages in)
    let base = deployment.infer(&op, &image).expect("infer");
    let tiled = deployment
        .infer_latency_opts(&op, &image, threads, false)
        .expect("infer_latency_opts");
    assert_eq!(base.logits, tiled.logits, "latency mode changed logits");

    let best_of = |f: &dyn Fn()| {
        let mut best = f64::INFINITY;
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            best = best.min(t0.elapsed().as_secs_f64() * 1e3);
        }
        best
    };
    let seq_ms = best_of(&|| {
        deployment.infer(&op, &image).expect("infer");
    });
    let tile_ms = best_of(&|| {
        deployment
            .infer_latency_opts(&op, &image, threads, false)
            .expect("infer_latency_opts");
    });
    Latency { threads, iters, seq_ms, tile_ms }
}

/// Hybrid batch x tile scheduler measurements over the persistent
/// `ExecPool`: pooled single-image latency (vs the sequential walk and
/// vs the legacy spawn-per-layer tiler at equal thread count), and
/// mid-size-batch throughput of the hybrid schedule vs the pure batch
/// schedule.
struct Hybrid {
    threads: usize,
    images: usize,
    iters: u32,
    seq_ms: f64,
    pool_ms: f64,
    respawn_ms: f64,
    batch_img_s: f64,
    hybrid_img_s: f64,
}

impl Hybrid {
    /// Pooled single-image speedup over the sequential walk — the
    /// persistent-pool analog of `speedup_tile`, trajectory-gated in
    /// CI.
    fn speedup_pool(&self) -> f64 {
        self.seq_ms / self.pool_ms
    }

    /// Pooled vs legacy spawn-per-layer latency at equal thread count —
    /// the recovered spawn overhead; gated >= the baseline so the pool
    /// can never silently lose to respawning.
    fn pool_vs_respawn(&self) -> f64 {
        self.respawn_ms / self.pool_ms
    }

    /// Hybrid vs pure-batch throughput on the mid-size batch
    /// (informational: the regime where the remainder tiles).
    fn speedup_hybrid(&self) -> f64 {
        self.hybrid_img_s / self.batch_img_s
    }

    fn to_json(&self) -> String {
        format!(
            " {{\n  \"threads\": {},\n  \"images\": {},\n  \
             \"iters\": {},\n  \"seq_ms\": {:.3},\n  \
             \"pool_ms\": {:.3},\n  \"respawn_ms\": {:.3},\n  \
             \"batch_img_s\": {:.3},\n  \"hybrid_img_s\": {:.3},\n  \
             \"speedup_pool\": {:.3},\n  \"pool_vs_respawn\": {:.3},\n  \
             \"speedup_hybrid\": {:.3}\n }}",
            self.threads,
            self.images,
            self.iters,
            self.seq_ms,
            self.pool_ms,
            self.respawn_ms,
            self.batch_img_s,
            self.hybrid_img_s,
            self.speedup_pool(),
            self.pool_vs_respawn(),
            self.speedup_hybrid()
        )
    }
}

/// Measure the pooled scheduler on the ResNet-20 example: single-image
/// latency through the persistent pool (vs sequential and vs the
/// legacy per-layer respawn tiler), and a threads + threads/2 mid-size
/// batch under the hybrid vs the batch schedule — asserting
/// bitwise-identical logits across every mode along the way.
fn hybrid_bench(smoke: bool) -> Hybrid {
    use marsellus::coordinator::{Coordinator, Schedule};
    use marsellus::dnn::{NetworkSpec, PrecisionConfig};
    use marsellus::power::OperatingPoint;
    use marsellus::util::Rng;

    let dir = marsellus::runtime::Runtime::resolve_artifacts_dir(None);
    let coord = Coordinator::new(dir).expect("coordinator");
    let spec = NetworkSpec::new("resnet20", PrecisionConfig::Mixed, 42);
    let op = OperatingPoint::at_vdd(0.8);
    let threads = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(4);
    let iters = if smoke { 5 } else { 15 };
    let deployment = coord.deploy(&spec).expect("deploy");
    let mut rng = Rng::new(0x9001);
    let image = deployment.random_input(&mut rng);

    // single image: sequential vs pooled vs legacy respawn, all equal
    let base = deployment.infer(&op, &image).expect("infer");
    let pooled = deployment
        .infer_latency(&op, &image, threads)
        .expect("infer_latency");
    assert_eq!(base.logits, pooled.logits, "pooled path changed logits");
    let best_of = |f: &dyn Fn()| {
        let mut best = f64::INFINITY;
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            best = best.min(t0.elapsed().as_secs_f64() * 1e3);
        }
        best
    };
    let seq_ms = best_of(&|| {
        deployment.infer(&op, &image).expect("infer");
    });
    let pool_ms = best_of(&|| {
        deployment
            .infer_latency(&op, &image, threads)
            .expect("infer_latency");
    });
    let respawn_ms = best_of(&|| {
        deployment
            .infer_latency_opts(&op, &image, threads, false)
            .expect("infer_latency_opts");
    });

    // mid-size batch (threads + threads/2): hybrid vs pure batch
    let n = threads + (threads / 2).max(1);
    let images: Vec<Vec<i32>> =
        (0..n).map(|_| deployment.random_input(&mut rng)).collect();
    let run = |sched: Schedule| {
        let t0 = Instant::now();
        let res = deployment
            .infer_scheduled(&op, &images, sched)
            .expect("infer_scheduled");
        let img_s = n as f64 / t0.elapsed().as_secs_f64();
        let logits: Vec<Vec<i32>> =
            res.into_iter().map(|r| r.logits).collect();
        (img_s, logits)
    };
    let (_, warm) = run(Schedule::batch(threads));
    let (batch_img_s, batch_logits) = run(Schedule::batch(threads));
    let (hybrid_img_s, hybrid_logits) = run(Schedule::hybrid(threads));
    assert_eq!(warm, batch_logits, "batch schedule is nondeterministic");
    assert_eq!(
        batch_logits, hybrid_logits,
        "hybrid schedule changed logits"
    );

    Hybrid {
        threads,
        images: n,
        iters,
        seq_ms,
        pool_ms,
        respawn_ms,
        batch_img_s,
        hybrid_img_s,
    }
}

/// Deploy-time autotuner measurements: pooled single-image latency of
/// the heuristically-configured deployment vs the tuned deployment on
/// the same machine. The tuner only ever keeps a candidate that beat
/// the heuristic in its own trials (ties keep the heuristic), so the
/// ratio is >= 1.0 up to timer noise — `ci/check_bench.py` gates it
/// against the committed 1.0 baseline.
struct Tuned {
    threads: usize,
    iters: u32,
    trials: u32,
    heuristic_ms: f64,
    tuned_ms: f64,
    hybrid_cutover: usize,
    tuned_layers: usize,
}

impl Tuned {
    /// Tuned vs heuristic pooled latency — the CI-gated floor.
    fn tuned_vs_heuristic(&self) -> f64 {
        self.heuristic_ms / self.tuned_ms
    }

    fn to_json(&self) -> String {
        format!(
            " {{\n  \"threads\": {},\n  \"iters\": {},\n  \
             \"trials\": {},\n  \"heuristic_ms\": {:.3},\n  \
             \"tuned_ms\": {:.3},\n  \"tuned_vs_heuristic\": {:.3},\n  \
             \"hybrid_cutover\": {},\n  \"tuned_layers\": {}\n }}",
            self.threads,
            self.iters,
            self.trials,
            self.heuristic_ms,
            self.tuned_ms,
            self.tuned_vs_heuristic(),
            self.hybrid_cutover,
            self.tuned_layers
        )
    }
}

/// Measure the autotuner on the ResNet-20 example: deploy with the
/// fixed heuristics, then re-deploy tuned (in-memory only — the bench
/// must not depend on persisted state), assert bitwise-identical
/// logits, and time pooled single-image latency on both deployments.
fn tuned_bench(smoke: bool) -> Tuned {
    use marsellus::coordinator::Coordinator;
    use marsellus::dnn::{NetworkSpec, PrecisionConfig};
    use marsellus::power::OperatingPoint;
    use marsellus::runtime::TuneOptions;
    use marsellus::util::Rng;

    let dir = marsellus::runtime::Runtime::resolve_artifacts_dir(None);
    let coord = Coordinator::new(dir).expect("coordinator");
    let spec = NetworkSpec::new("resnet20", PrecisionConfig::Mixed, 42);
    let op = OperatingPoint::at_vdd(0.8);
    let threads = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(4);
    let iters = if smoke { 5 } else { 15 };
    let trials = if smoke { 2 } else { 3 };
    // Heuristic deployment FIRST: its Arc keeps the plan alive after
    // deploy_tuned replaces the cache resident with the tuned plan.
    let heuristic = coord.deploy(&spec).expect("deploy");
    let tuned = coord
        .deploy_tuned(&spec, &TuneOptions::new(threads, trials))
        .expect("deploy_tuned");
    let cfg = tuned.tuned().expect("tuned config").clone();
    let mut rng = Rng::new(0x7E57);
    let image = heuristic.random_input(&mut rng);

    // tuning changes speed, never logits
    let base = heuristic.infer(&op, &image).expect("infer");
    let tuned_seq = tuned.infer(&op, &image).expect("infer");
    assert_eq!(base.logits, tuned_seq.logits, "tuned plan changed logits");
    let tuned_pool = tuned
        .infer_latency(&op, &image, threads)
        .expect("infer_latency");
    assert_eq!(
        base.logits, tuned_pool.logits,
        "tuned pooled path changed logits"
    );

    let best_of = |f: &dyn Fn()| {
        let mut best = f64::INFINITY;
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            best = best.min(t0.elapsed().as_secs_f64() * 1e3);
        }
        best
    };
    let heuristic_ms = best_of(&|| {
        heuristic
            .infer_latency(&op, &image, threads)
            .expect("infer_latency");
    });
    let tuned_ms = best_of(&|| {
        tuned
            .infer_latency(&op, &image, threads)
            .expect("infer_latency");
    });

    let tuned_layers =
        cfg.layers.iter().filter(|l| l.speedup() > 1.0).count();
    Tuned {
        threads,
        iters,
        trials,
        heuristic_ms,
        tuned_ms,
        hybrid_cutover: cfg.hybrid_cutover(),
        tuned_layers,
    }
}

/// Process-wide runtime measurements: the same batch served through a
/// per-call `Owned` pool (threads provisioned and joined inside the
/// call) vs the shared `Global` runtime (workers pre-exist the call),
/// plus two tenants submitting concurrently vs back-to-back.
struct GlobalRt {
    threads: usize,
    images: usize,
    iters: u32,
    owned_ms: f64,
    global_ms: f64,
    serial_img_s: f64,
    concurrent_img_s: f64,
}

impl GlobalRt {
    /// Shared-runtime vs per-call-provisioned batch latency — the
    /// recovered provisioning overhead; gated >= 1.0 so the global
    /// runtime can never silently lose to respawning pools.
    fn reuse_vs_provision(&self) -> f64 {
        self.owned_ms / self.global_ms
    }

    /// Two tenants overlapping on the shared runtime vs serving them
    /// back-to-back (informational: contention vs pipelining).
    fn concurrent_vs_serial(&self) -> f64 {
        self.concurrent_img_s / self.serial_img_s
    }

    fn to_json(&self) -> String {
        format!(
            " {{\n  \"threads\": {},\n  \"images\": {},\n  \
             \"iters\": {},\n  \"owned_ms\": {:.3},\n  \
             \"global_ms\": {:.3},\n  \"serial_img_s\": {:.3},\n  \
             \"concurrent_img_s\": {:.3},\n  \
             \"reuse_vs_provision\": {:.3},\n  \
             \"concurrent_vs_serial\": {:.3}\n }}",
            self.threads,
            self.images,
            self.iters,
            self.owned_ms,
            self.global_ms,
            self.serial_img_s,
            self.concurrent_img_s,
            self.reuse_vs_provision(),
            self.concurrent_vs_serial()
        )
    }
}

/// Measure the process-wide runtime: a `threads`-image batch through
/// the Owned A/B pool vs the Global runtime (bitwise-equal logits
/// asserted), then two tenants (ResNet-20 + KWS) served back-to-back
/// vs concurrently on the shared workers.
fn global_bench(smoke: bool) -> GlobalRt {
    use marsellus::coordinator::{Coordinator, Schedule};
    use marsellus::dnn::{NetworkSpec, PrecisionConfig};
    use marsellus::power::OperatingPoint;
    use marsellus::runtime::ExecRuntime;
    use marsellus::util::Rng;

    let dir = marsellus::runtime::Runtime::resolve_artifacts_dir(None);
    let coord = Coordinator::new(dir).expect("coordinator");
    let op = OperatingPoint::at_vdd(0.8);
    let threads = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(4);
    let iters = if smoke { 5 } else { 15 };
    let resnet = coord
        .deploy(&NetworkSpec::new("resnet20", PrecisionConfig::Mixed, 42))
        .expect("deploy resnet20");
    let kws = coord
        .deploy(&NetworkSpec::new("kws", PrecisionConfig::Mixed, 7))
        .expect("deploy kws");
    let mut rng = Rng::new(0x610B);
    let n = threads.max(2);
    let res_images: Vec<Vec<i32>> =
        (0..n).map(|_| resnet.random_input(&mut rng)).collect();
    let kws_images: Vec<Vec<i32>> =
        (0..n).map(|_| kws.random_input(&mut rng)).collect();

    let batch = |d: &marsellus::coordinator::Deployment<'_>,
                 images: &[Vec<i32>],
                 rt: ExecRuntime| {
        d.infer_scheduled_on(&op, images, Schedule::batch(threads), rt)
            .expect("infer_scheduled_on")
            .into_iter()
            .map(|r| r.logits)
            .collect::<Vec<_>>()
    };
    // warm both paths (spawns the global fleet once) and pin parity
    let owned_logits = batch(&resnet, &res_images, ExecRuntime::Owned);
    let global_logits = batch(&resnet, &res_images, ExecRuntime::Global);
    assert_eq!(
        owned_logits, global_logits,
        "Owned and Global runtimes diverged"
    );

    let best_of = |f: &dyn Fn()| {
        let mut best = f64::INFINITY;
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            best = best.min(t0.elapsed().as_secs_f64() * 1e3);
        }
        best
    };
    let owned_ms = best_of(&|| {
        batch(&resnet, &res_images, ExecRuntime::Owned);
    });
    let global_ms = best_of(&|| {
        batch(&resnet, &res_images, ExecRuntime::Global);
    });

    // two tenants: back-to-back vs overlapping on the shared runtime
    let total = 2 * n;
    let mut serial_img_s = 0.0;
    let mut concurrent_img_s = 0.0;
    for _ in 0..iters {
        let t0 = Instant::now();
        batch(&resnet, &res_images, ExecRuntime::Global);
        batch(&kws, &kws_images, ExecRuntime::Global);
        serial_img_s =
            serial_img_s.max(total as f64 / t0.elapsed().as_secs_f64());
        let t0 = Instant::now();
        std::thread::scope(|s| {
            s.spawn(|| batch(&resnet, &res_images, ExecRuntime::Global));
            s.spawn(|| batch(&kws, &kws_images, ExecRuntime::Global));
        });
        concurrent_img_s = concurrent_img_s
            .max(total as f64 / t0.elapsed().as_secs_f64());
    }

    GlobalRt {
        threads,
        images: n,
        iters,
        owned_ms,
        global_ms,
        serial_img_s,
        concurrent_img_s,
    }
}

/// Serving-gateway measurements: the same 2-tenant mixed-size workload
/// served through the admission gateway vs called directly on the
/// deployment API (logits asserted bitwise equal), plus each tenant's
/// exact p99 latency under interleaved sustained load.
struct GatewayBench {
    threads: usize,
    images: usize,
    iters: u32,
    direct_ms: f64,
    gateway_ms: f64,
    a_p99_us: f64,
    b_p99_us: f64,
    reap_enabled_ms: f64,
    reap_disabled_ms: f64,
}

impl GatewayBench {
    /// Direct-call vs through-the-gateway wall clock for the identical
    /// workload — the admission/dispatch overhead. Gated >= 0.9 (exact,
    /// no extra tolerance) so the gateway can never cost more than 10%
    /// of the serving path it fronts.
    fn gateway_vs_direct(&self) -> f64 {
        self.direct_ms / self.gateway_ms
    }

    /// min/max of the two tenants' p99 latencies under interleaved
    /// equal-priority load — 1.0 is perfectly fair, small values mean
    /// one tenant starves. Computed from exact per-ticket latencies
    /// (`Completed::queued + service`), not the telemetry histogram's
    /// log2 buckets, so the ratio is not quantized to powers of two.
    fn fair_p99_ratio(&self) -> f64 {
        let (lo, hi) = if self.a_p99_us <= self.b_p99_us {
            (self.a_p99_us, self.b_p99_us)
        } else {
            (self.b_p99_us, self.a_p99_us)
        };
        if hi <= 0.0 {
            return 1.0;
        }
        lo / hi
    }

    /// Non-reaping vs reaping gateway wall clock for the identical
    /// far-deadline workload (nothing ever expires, so the two do the
    /// same serving work). Gated >= 0.95 (exact) so the deadline
    /// reaper's sweeps and timed wakeups can never cost more than 5%
    /// on a workload where it sheds nothing.
    fn reap_overhead(&self) -> f64 {
        self.reap_disabled_ms / self.reap_enabled_ms
    }

    fn to_json(&self) -> String {
        format!(
            " {{\n  \"threads\": {},\n  \"images\": {},\n  \
             \"iters\": {},\n  \"direct_ms\": {:.3},\n  \
             \"gateway_ms\": {:.3},\n  \"a_p99_us\": {:.1},\n  \
             \"b_p99_us\": {:.1},\n  \"reap_enabled_ms\": {:.3},\n  \
             \"reap_disabled_ms\": {:.3},\n  \
             \"gateway_vs_direct\": {:.3},\n  \
             \"fair_p99_ratio\": {:.3},\n  \"reap_overhead\": {:.3}\n }}",
            self.threads,
            self.images,
            self.iters,
            self.direct_ms,
            self.gateway_ms,
            self.a_p99_us,
            self.b_p99_us,
            self.reap_enabled_ms,
            self.reap_disabled_ms,
            self.gateway_vs_direct(),
            self.fair_p99_ratio(),
            self.reap_overhead()
        )
    }
}

/// Exact quantile from raw per-ticket latency samples.
fn quantile(samples: &mut [f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let idx = ((samples.len() as f64 * q).ceil() as usize)
        .clamp(1, samples.len())
        - 1;
    samples[idx]
}

/// Measure the serving gateway: two tenants submit an interleaved
/// mixed-size workload (`interactive`: single-image ResNet-20,
/// `bulk`: 4-image KWS batches, equal priority) through the gateway
/// and directly on the deployment API. Asserts the gateway's logits
/// bitwise equal to the direct path's.
fn gateway_bench(smoke: bool) -> GatewayBench {
    use marsellus::coordinator::Coordinator;
    use marsellus::dnn::{NetworkSpec, PrecisionConfig};
    use marsellus::gateway::{
        pick_schedule, Gateway, GatewayConfig, Priority,
    };
    use marsellus::power::OperatingPoint;
    use marsellus::runtime::ExecRuntime;
    use marsellus::util::Rng;
    use std::sync::Arc;

    let dir = marsellus::runtime::Runtime::resolve_artifacts_dir(None);
    let coord =
        Arc::new(Coordinator::new(dir).expect("coordinator"));
    let op = OperatingPoint::at_vdd(0.8);
    let threads = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(4);
    let iters = if smoke { 3 } else { 8 };
    let per_tenant = if smoke { 4 } else { 6 };

    let a_spec = NetworkSpec::new("resnet20", PrecisionConfig::Mixed, 42);
    let b_spec = NetworkSpec::new("kws", PrecisionConfig::Mixed, 7);
    let resnet = coord.deploy(&a_spec).expect("deploy resnet20");
    let kws = coord.deploy(&b_spec).expect("deploy kws");
    let mut rng = Rng::new(0x6A7E);
    // interleaved a,b,a,b… — (tenant, spec, images) per request
    let workload: Vec<(&str, &NetworkSpec, Vec<Vec<i32>>)> = (0
        ..per_tenant)
        .flat_map(|_| {
            [
                ("interactive", &a_spec, vec![resnet
                    .random_input(&mut rng)]),
                (
                    "bulk",
                    &b_spec,
                    (0..4).map(|_| kws.random_input(&mut rng)).collect(),
                ),
            ]
        })
        .collect();
    let images: usize = workload.iter().map(|(_, _, i)| i.len()).sum();

    let direct = |collect: bool| -> Vec<Vec<Vec<i32>>> {
        let mut logits = Vec::new();
        for (_, spec, imgs) in &workload {
            let d = coord.deploy(spec).expect("deploy");
            let out = d
                .infer_scheduled_on(
                    &op,
                    imgs,
                    pick_schedule(imgs.len(), threads),
                    ExecRuntime::Global,
                )
                .expect("direct infer");
            if collect {
                logits
                    .push(out.into_iter().map(|r| r.logits).collect());
            }
        }
        logits
    };
    let cfg = GatewayConfig {
        queue_depth: workload.len() * 2,
        per_tenant_inflight: workload.len(),
        threads: 0,
        ..GatewayConfig::default()
    };
    let gateway =
        Gateway::new(coord.clone(), cfg.clone()).expect("gateway");
    let mut a_lat_us: Vec<f64> = Vec::new();
    let mut b_lat_us: Vec<f64> = Vec::new();
    let mut through = |collect: bool| -> Vec<Vec<Vec<i32>>> {
        let tickets: Vec<_> = workload
            .iter()
            .map(|(tenant, spec, imgs)| {
                (
                    *tenant,
                    gateway
                        .submit(
                            tenant,
                            spec,
                            &op,
                            imgs.clone(),
                            Priority::Normal,
                            None,
                        )
                        .expect("admission"),
                )
            })
            .collect();
        let mut logits = Vec::new();
        for (tenant, ticket) in tickets {
            let done = ticket.wait().expect("gateway result");
            let us =
                (done.queued + done.service).as_secs_f64() * 1e6;
            if tenant == "interactive" {
                a_lat_us.push(us);
            } else {
                b_lat_us.push(us);
            }
            if collect {
                logits.push(
                    done.results
                        .into_iter()
                        .map(|r| r.logits)
                        .collect(),
                );
            }
        }
        logits
    };

    // warm both paths and pin bitwise parity gateway <-> direct
    let direct_logits = direct(true);
    let gateway_logits = through(true);
    assert_eq!(
        direct_logits, gateway_logits,
        "gateway and direct serving paths diverged"
    );

    let mut direct_ms = f64::INFINITY;
    let mut gateway_ms = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        direct(false);
        direct_ms = direct_ms.min(t0.elapsed().as_secs_f64() * 1e3);
        let t0 = Instant::now();
        through(false);
        gateway_ms = gateway_ms.min(t0.elapsed().as_secs_f64() * 1e3);
    }

    // deadline-reap overhead: the identical workload under far (60s)
    // deadlines — nothing ever expires, so a reaping and a non-reaping
    // gateway do the same serving work and the wall-clock ratio
    // isolates the reaper's sweep + timed-wakeup cost.
    use std::time::Duration;
    let timed = |gw: &Gateway| -> f64 {
        let t0 = Instant::now();
        let tickets: Vec<_> = workload
            .iter()
            .map(|(tenant, spec, imgs)| {
                gw.submit(
                    tenant,
                    spec,
                    &op,
                    imgs.clone(),
                    Priority::Normal,
                    Some(Duration::from_secs(60)),
                )
                .expect("admission")
            })
            .collect();
        for t in tickets {
            t.wait().expect("gateway result");
        }
        t0.elapsed().as_secs_f64() * 1e3
    };
    let reaping = Gateway::new(
        coord.clone(),
        GatewayConfig { shed_expired: true, ..cfg.clone() },
    )
    .expect("gateway (reap on)");
    let non_reaping = Gateway::new(
        coord.clone(),
        GatewayConfig { shed_expired: false, ..cfg },
    )
    .expect("gateway (reap off)");
    timed(&reaping); // warm both
    timed(&non_reaping);
    let mut reap_enabled_ms = f64::INFINITY;
    let mut reap_disabled_ms = f64::INFINITY;
    for _ in 0..iters {
        reap_enabled_ms = reap_enabled_ms.min(timed(&reaping));
        reap_disabled_ms = reap_disabled_ms.min(timed(&non_reaping));
    }

    GatewayBench {
        threads,
        images,
        iters,
        direct_ms,
        gateway_ms,
        a_p99_us: quantile(&mut a_lat_us, 0.99),
        b_p99_us: quantile(&mut b_lat_us, 0.99),
        reap_enabled_ms,
        reap_disabled_ms,
    }
}

fn write_json(
    path: &str,
    mode: &str,
    results: &[BenchResult],
    total: f64,
    throughput: &Throughput,
    latency: &Latency,
    hybrid: &Hybrid,
    tuned: &Tuned,
    global_rt: &GlobalRt,
    gateway: &GatewayBench,
) {
    let resolved = resolve_out_path(path);
    let path = resolved.display().to_string();
    let path = path.as_str();
    let mut rows = Vec::new();
    for r in results {
        rows.push(format!(
            "  {{\"id\": \"{}\", \"best_ms\": {:.3}, \"iters\": {}, \
             \"headline\": \"{}\"}}",
            r.id,
            r.best_ms,
            r.iters,
            json_escape(&r.headline)
        ));
    }
    let doc = format!(
        "{{\n \"mode\": \"{mode}\",\n \"total_best_ms\": {total:.3},\n \
         \"throughput\":\n{},\n \"latency\":\n{},\n \
         \"hybrid\":\n{},\n \"tuned\":\n{},\n \"global\":\n{},\n \
         \"gateway\":\n{},\n \"benches\": [\n{}\n ]\n}}\n",
        throughput.to_json(),
        latency.to_json(),
        hybrid.to_json(),
        tuned.to_json(),
        global_rt.to_json(),
        gateway.to_json(),
        rows.join(",\n")
    );
    if let Err(e) = std::fs::write(path, doc) {
        eprintln!("warning: could not write {path}: {e}");
    } else {
        println!("wrote {path}");
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let flag = |f: &str| argv.iter().any(|a| a == f);
    // `cargo bench -- --smoke` (or --quick): the CI subset
    let smoke = flag("--smoke") || flag("--quick");
    let json_path = argv
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned();

    // figures sorted cheap-to-expensive; heavy ISS figures get 1 iter
    let full_plan: &[(&str, u32)] = &[
        ("fig7", 5),
        ("fig8", 5),
        ("fig9", 5),
        ("fig10", 5),
        ("fig13", 5),
        ("tab1", 3),
        ("fig11", 3),
        ("fig12", 3),
        ("fig17", 3),
        ("fig18", 3),
        ("fig15", 1),
        ("fig19", 1),
        ("isa", 1),
        ("tab2", 1),
        ("fig14", 1),
        ("ablate-ml", 1),
        ("ablate-dbuf", 3),
        ("ablate-abb", 1),
        ("ablate-banks", 1),
    ];
    // smoke: the cheap generators only, one iteration — enough to keep a
    // comparable perf trajectory across CI runs without the ISS-heavy
    // figures' minutes of wall clock
    let smoke_plan: &[(&str, u32)] = &[
        ("fig7", 1),
        ("fig8", 1),
        ("fig9", 1),
        ("fig10", 1),
        ("fig13", 1),
        ("tab1", 1),
        ("fig17", 1),
        ("fig18", 1),
    ];
    let plan = if smoke { smoke_plan } else { full_plan };

    println!(
        "paper reproduction benches (one per table/figure; \
         min over N iters){}\n",
        if smoke { " [smoke]" } else { "" }
    );
    println!("{:<8} {:>10} {:>6}   headline", "bench", "best ms", "iters");
    println!("{}", "-".repeat(78));
    let mut total = 0.0;
    let mut results = Vec::new();
    for &(id, iters) in plan {
        let r = bench(id, iters);
        println!(
            "{:<8} {:>10.1} {:>6}   {}",
            r.id,
            r.best_ms,
            r.iters,
            &r.headline[..r.headline.len().min(48)]
        );
        total += r.best_ms;
        results.push(r);
    }
    println!("{}", "-".repeat(78));
    println!("total (best-iteration sum): {total:.0} ms");

    // serving throughput: pre-plan vs LayerPlan vs parallel worker pool
    println!("\ninfer_batch serving throughput (ResNet-20 mixed, native)");
    let thr = throughput_bench(smoke);
    println!(
        "  per-call path   {:>8.2} img/s  (1 thread, pre-plan baseline)",
        thr.per_call_img_s
    );
    println!(
        "  LayerPlan path  {:>8.2} img/s  (1 thread, {:.2}x)",
        thr.planned_img_s,
        thr.speedup_planned()
    );
    println!(
        "  worker pool     {:>8.2} img/s  ({} threads, {:.2}x)",
        thr.parallel_img_s,
        thr.threads,
        thr.speedup_parallel()
    );
    println!("\nper-layer setup-vs-compute split (one image)");
    print!("{}", marsellus::metrics::render_setup_compute(&thr.layers));

    // single-image latency: sequential walk vs tile-parallel mode
    println!("\nsingle-image latency (ResNet-20 mixed, best of N)");
    let lat = latency_bench(smoke);
    println!(
        "  sequential      {:>8.2} ms/img  (1 thread)",
        lat.seq_ms
    );
    println!(
        "  respawn tiler   {:>8.2} ms/img  ({} tile workers, {:.2}x, \
         legacy)",
        lat.tile_ms,
        lat.threads,
        lat.speedup_tile()
    );

    // persistent pool: pooled latency + hybrid batch x tile scheduling
    println!("\npersistent-pool scheduler (ResNet-20 mixed, best of N)");
    let hyb = hybrid_bench(smoke);
    println!(
        "  pooled latency  {:>8.2} ms/img  ({} workers, {:.2}x vs seq, \
         {:.2}x vs respawn)",
        hyb.pool_ms,
        hyb.threads,
        hyb.speedup_pool(),
        hyb.pool_vs_respawn()
    );
    println!(
        "  batch schedule  {:>8.2} img/s  ({} images, {} workers)",
        hyb.batch_img_s, hyb.images, hyb.threads
    );
    println!(
        "  hybrid schedule {:>8.2} img/s  ({:.2}x vs batch)",
        hyb.hybrid_img_s,
        hyb.speedup_hybrid()
    );

    // deploy-time autotuner: tuned vs heuristic pooled latency
    println!("\ndeploy-time autotuner (ResNet-20 mixed, best of N)");
    let tun = tuned_bench(smoke);
    println!(
        "  heuristic cfg   {:>8.2} ms/img  ({} workers, fixed picks)",
        tun.heuristic_ms, tun.threads
    );
    println!(
        "  tuned cfg       {:>8.2} ms/img  ({:.2}x vs heuristic, \
         {} layer pick(s), cutover {}; gated >= 1.0)",
        tun.tuned_ms,
        tun.tuned_vs_heuristic(),
        tun.tuned_layers,
        tun.hybrid_cutover
    );

    // process-wide runtime: reuse vs per-call provisioning, 2 tenants
    println!("\nglobal work-stealing runtime (batch of {}, best of N)", {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4).max(2)
    });
    let glo = global_bench(smoke);
    println!(
        "  owned pool      {:>8.2} ms/batch  ({} workers provisioned \
         per call)",
        glo.owned_ms, glo.threads
    );
    println!(
        "  global runtime  {:>8.2} ms/batch  ({:.2}x vs owned; gated \
         >= 1.0)",
        glo.global_ms,
        glo.reuse_vs_provision()
    );
    println!(
        "  2-tenant serial {:>8.2} img/s  (ResNet-20 + KWS back-to-back)",
        glo.serial_img_s
    );
    println!(
        "  2-tenant concur {:>8.2} img/s  ({:.2}x vs serial, shared \
         workers)",
        glo.concurrent_img_s,
        glo.concurrent_vs_serial()
    );

    // serving gateway: 2-tenant mixed-size workload, gateway vs direct
    println!("\nserving gateway (2 tenants, interleaved, best of N)");
    let gtw = gateway_bench(smoke);
    println!(
        "  direct calls    {:>8.2} ms/workload  ({} images, {} lanes)",
        gtw.direct_ms, gtw.images, gtw.threads
    );
    println!(
        "  via gateway     {:>8.2} ms/workload  ({:.2}x vs direct; \
         gated >= 0.9)",
        gtw.gateway_ms,
        gtw.gateway_vs_direct()
    );
    println!(
        "  tenant p99      {:>8.0} us (interactive) / {:.0} us (bulk), \
         fairness {:.2}",
        gtw.a_p99_us,
        gtw.b_p99_us,
        gtw.fair_p99_ratio()
    );
    println!(
        "  deadline reaper {:>8.2} ms/workload on vs {:.2} ms off \
         ({:.2}x; gated >= 0.95)",
        gtw.reap_enabled_ms,
        gtw.reap_disabled_ms,
        gtw.reap_overhead()
    );

    if let Some(path) = json_path {
        write_json(
            &path,
            if smoke { "smoke" } else { "full" },
            &results,
            total,
            &thr,
            &lat,
            &hyb,
            &tun,
            &glo,
            &gtw,
        );
    }

    if !smoke {
        // kernel micro-benches: simulator throughput on the hot paths
        println!("\nsimulator hot-path micro-benches");
        micro_benches();
    }
}

fn micro_benches() {
    use marsellus::cluster::ClusterConfig;
    use marsellus::isa::Prec;
    use marsellus::kernels::matmul::{
        random_operands, MatmulKernel, MatmulProblem,
    };
    use marsellus::rbe::functional::{conv_bitserial, NormQuant};
    use marsellus::rbe::RbeJob;
    use marsellus::util::Rng;

    // ISS throughput: simulated instructions per host second (best of 3
    // on a ~1M-instruction workload to stay above timer noise)
    let p = MatmulProblem {
        m: 128,
        n: 32,
        k: 256,
        kernel: MatmulKernel::MacLoad { prec: Prec::B8 },
        cores: 16,
    };
    let (a, b) = random_operands(p.m, p.n, p.k, Prec::B8, 1);
    let mut best = f64::INFINITY;
    let mut instrs = 0;
    for _ in 0..3 {
        let t0 = Instant::now();
        let (_, stats) =
            p.run_with(ClusterConfig::default(), &a, &b).unwrap();
        best = best.min(t0.elapsed().as_secs_f64());
        instrs = stats.total.instrs;
    }
    println!(
        "  ISS 16-core matmul: {:.1} M simulated instr/s \
         ({} instrs in {:.0} ms)",
        instrs as f64 / best / 1e6,
        instrs,
        best * 1e3
    );

    // functional RBE model throughput
    let job = RbeJob::conv3x3(8, 8, 32, 32, 1, 4, 4, 4).unwrap();
    let mut rng = Rng::new(2);
    let x: Vec<i32> = (0..10 * 10 * 32).map(|_| rng.range_i32(0, 16)).collect();
    let w: Vec<i32> =
        (0..32 * 32 * 9).map(|_| rng.range_i32(-8, 8)).collect();
    let nq = NormQuant::unit(32);
    let t0 = Instant::now();
    let _ = conv_bitserial(&job, &x, &w, &nq).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "  bit-serial RBE functional: {:.1} M MAC/s ({} MACs in {:.0} ms)",
        job.macs() as f64 / dt / 1e6,
        job.macs(),
        dt * 1e3
    );
}
