#!/usr/bin/env python3
"""Render the committed bench history into a static trend page.

Reads ``ci/BENCH_history.jsonl`` (one JSON row per main-branch commit,
appended by ``bench_history.py``) and writes two artifacts:

* ``bench_trend.md`` — a table view of the recent history plus a
  min/median/latest summary per gated ratio, readable in any terminal
  or PR comment;
* ``bench_trend.html`` — small-multiple line charts (one per recorded
  ratio, single series each, shared x axis of commits) so the
  trajectories ``check_bench.py`` gates are visible at a glance.
  Ratios the history records beyond the handcrafted ``SERIES`` list
  are discovered and rendered with a generic title, so a newly gated
  section is never silently dropped from the page.
  Self-contained: no external assets, light/dark via
  ``prefers-color-scheme``.

The bench-smoke CI job uploads both as the ``bench-trend`` artifact.

Usage: bench_trend.py HISTORY.jsonl [--out-dir DIR]
"""

import json
import os
import sys

# Gated / headline ratios, in render order: (key, chart title).
SERIES = (
    ("speedup_planned", "throughput: plan vs per-call"),
    ("speedup_parallel", "throughput: worker pool vs per-call"),
    ("speedup_tile", "latency: respawn tiler vs sequential"),
    ("speedup_pool", "hybrid: persistent pool vs sequential"),
    ("pool_vs_respawn", "hybrid: pool vs respawn tiler"),
    ("speedup_hybrid", "hybrid: hybrid vs batch schedule"),
    ("tuned_vs_heuristic", "tuned: autotuned vs heuristic config"),
    ("reuse_vs_provision", "global: shared fleet vs per-call pool"),
    ("concurrent_vs_serial", "global: 2 tenants concurrent vs serial"),
    ("gateway_vs_direct", "gateway: via gateway vs direct calls"),
    ("fair_p99_ratio", "gateway: 2-tenant p99 fairness"),
)

# Machine-dependent context keys recorded for reading the history, not
# charted: anything dimensioned (ms / us / img_s), thread counts, and
# the row identity fields.
CONTEXT_SUFFIXES = ("_ms", "_us", "_img_s", "_threads", "_cutover")
CONTEXT_KEYS = {"commit", "mode", "threads"}


def discovered_series(rows):
    """Ratio keys present in the history that SERIES has no entry for.

    A newly gated bench section starts rendering (with a generic title)
    the moment bench_history.py records its ratio — the page can never
    silently drop a trajectory because this file lacks a handcrafted
    template for it.
    """
    known = {k for k, _ in SERIES}
    found = []
    for r in rows:
        for k, v in r.items():
            if (
                k in known
                or k in CONTEXT_KEYS
                or k.endswith(CONTEXT_SUFFIXES)
                or not isinstance(v, (int, float))
                or isinstance(v, bool)
            ):
                continue
            known.add(k)
            found.append((k, f"{k} (recorded ratio)"))
    return sorted(found)


def all_series(rows):
    """SERIES plus any ratios the history records beyond it."""
    return tuple(SERIES) + tuple(discovered_series(rows))

# How many trailing history rows the table shows.
TABLE_ROWS = 20

# Chart geometry (px).
W, H = 360, 150
PAD_L, PAD_R, PAD_T, PAD_B = 44, 16, 24, 22

CSS = """\
:root {
  color-scheme: light;
  --surface-1: #fcfcfb;
  --text-primary: #0b0b0b;
  --text-secondary: #52514e;
  --grid: #e4e3df;
  --series-1: #2a78d6;
}
@media (prefers-color-scheme: dark) {
  :root {
    color-scheme: dark;
    --surface-1: #1a1a19;
    --text-primary: #ffffff;
    --text-secondary: #c3c2b7;
    --grid: #3a3937;
    --series-1: #3987e5;
  }
}
body {
  background: var(--surface-1);
  color: var(--text-primary);
  font: 13px/1.45 system-ui, sans-serif;
  margin: 24px;
}
h1 { font-size: 17px; }
p.sub { color: var(--text-secondary); max-width: 60em; }
.grid { display: flex; flex-wrap: wrap; gap: 20px; }
figure { margin: 0; }
figcaption { color: var(--text-secondary); font-size: 12px; }
svg text { fill: var(--text-secondary); font: 10px system-ui, sans-serif; }
svg text.val { fill: var(--text-primary); font-weight: 600; }
svg .axis { stroke: var(--grid); stroke-width: 1; }
svg .line { stroke: var(--series-1); stroke-width: 2; fill: none; }
svg .dot { fill: var(--series-1); stroke: var(--surface-1);
           stroke-width: 2; }
"""


def read_history(path):
    rows = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    rows.append(json.loads(line))
    except FileNotFoundError:
        pass
    return rows


def values_of(rows, key):
    """(row index, value) pairs for rows that record `key`."""
    out = []
    for i, r in enumerate(rows):
        v = r.get(key)
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            out.append((i, float(v)))
    return out


def median(xs):
    s = sorted(xs)
    mid = len(s) // 2
    return s[mid] if len(s) % 2 else (s[mid - 1] + s[mid]) / 2.0


def short_commit(row):
    c = str(row.get("commit", "?"))
    return c[:10] if len(c) > 10 else c


def chart_svg(rows, key, title):
    """One single-series line chart (returns '' when the key has no
    recorded history)."""
    pts = values_of(rows, key)
    if not pts:
        return ""
    lo = min(v for _, v in pts)
    hi = max(v for _, v in pts)
    span = (hi - lo) or max(abs(hi), 0.5)
    lo, hi = lo - 0.15 * span, hi + 0.15 * span
    n = len(rows)
    xw = W - PAD_L - PAD_R
    yh = H - PAD_T - PAD_B

    def x(i):
        return PAD_L + (xw / 2 if n <= 1 else i * xw / (n - 1))

    def y(v):
        return PAD_T + (hi - v) / (hi - lo) * yh

    out = [
        f'<svg viewBox="0 0 {W} {H}" width="{W}" height="{H}" '
        f'role="img" aria-label="{title}">'
    ]
    # recessive grid: 3 horizontal rules + y tick labels
    for t in range(3):
        gv = lo + (hi - lo) * (t + 0.5) / 3
        gy = y(gv)
        out.append(
            f'<line class="axis" x1="{PAD_L}" y1="{gy:.1f}" '
            f'x2="{W - PAD_R}" y2="{gy:.1f}"/>'
        )
        out.append(
            f'<text x="{PAD_L - 4}" y="{gy + 3:.1f}" '
            f'text-anchor="end">{gv:.2f}</text>'
        )
    # baseline axis
    out.append(
        f'<line class="axis" x1="{PAD_L}" y1="{H - PAD_B}" '
        f'x2="{W - PAD_R}" y2="{H - PAD_B}"/>'
    )
    # first/last commit labels on the x axis
    out.append(
        f'<text x="{PAD_L}" y="{H - 6}">{short_commit(rows[pts[0][0]])}'
        "</text>"
    )
    if len(pts) > 1:
        out.append(
            f'<text x="{W - PAD_R}" y="{H - 6}" text-anchor="end">'
            f"{short_commit(rows[pts[-1][0]])}</text>"
        )
    # the series: 2px line, hoverable >=8px markers, last value labeled
    path = " ".join(
        f"{'M' if k == 0 else 'L'}{x(i):.1f},{y(v):.1f}"
        for k, (i, v) in enumerate(pts)
    )
    out.append(f'<path class="line" d="{path}"/>')
    for i, v in pts:
        out.append(
            f'<circle class="dot" cx="{x(i):.1f}" cy="{y(v):.1f}" r="4">'
            f"<title>{short_commit(rows[i])}: {key} = {v:.3f}</title>"
            "</circle>"
        )
    li, lv = pts[-1]
    out.append(
        f'<text class="val" x="{min(x(li) + 6, W - PAD_R):.1f}" '
        f'y="{max(y(lv) - 7, 10):.1f}" text-anchor="end">{lv:.2f}</text>'
    )
    out.append("</svg>")
    return "".join(out)


def render_html(rows):
    figs = []
    for key, title in all_series(rows):
        svg = chart_svg(rows, key, title)
        if svg:
            figs.append(
                f"<figure>{svg}<figcaption>{title} "
                f"(<code>{key}</code>)</figcaption></figure>"
            )
    return (
        "<!doctype html><html><head><meta charset='utf-8'>"
        "<title>bench trend</title>"
        f"<style>{CSS}</style></head><body>"
        "<h1>Bench trajectory</h1>"
        "<p class='sub'>Machine-independent speedup ratios per "
        "main-branch commit (ci/BENCH_history.jsonl). check_bench.py "
        "gates each ratio against the median of its recent history, "
        "floored at the frozen baseline.</p>"
        f"<div class='grid'>{''.join(figs)}</div>"
        "</body></html>\n"
    )


def render_markdown(rows):
    lines = ["# Bench trajectory", ""]
    keys = [k for k, _ in all_series(rows) if values_of(rows, k)]
    if not keys:
        lines.append("_no recorded history yet_")
        return "\n".join(lines) + "\n"
    lines.append("| ratio | min | median | latest | n |")
    lines.append("|---|---|---|---|---|")
    for k in keys:
        vs = [v for _, v in values_of(rows, k)]
        lines.append(
            f"| `{k}` | {min(vs):.3f} | {median(vs):.3f} | {vs[-1]:.3f} "
            f"| {len(vs)} |"
        )
    lines += ["", f"## Last {min(TABLE_ROWS, len(rows))} commits", ""]
    lines.append("| commit | mode | " + " | ".join(keys) + " |")
    lines.append("|---" * (2 + len(keys)) + "|")
    for r in rows[-TABLE_ROWS:]:
        cells = [short_commit(r), str(r.get("mode", "?"))]
        for k in keys:
            v = r.get(k)
            cells.append(f"{v:.3f}" if isinstance(v, (int, float)) else "-")
        lines.append("| " + " | ".join(cells) + " |")
    return "\n".join(lines) + "\n"


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--")]
    out_dir = "bench-trend"
    if "--out-dir" in argv:
        i = argv.index("--out-dir")
        if i + 1 >= len(argv):
            print("error: --out-dir needs a path")
            return 2
        out_dir = argv[i + 1]
        if out_dir in args:
            args.remove(out_dir)
    if len(args) != 1:
        print(__doc__)
        return 2
    rows = read_history(args[0])
    os.makedirs(out_dir, exist_ok=True)
    md = os.path.join(out_dir, "bench_trend.md")
    html = os.path.join(out_dir, "bench_trend.html")
    with open(md, "w") as f:
        f.write(render_markdown(rows))
    with open(html, "w") as f:
        f.write(render_html(rows))
    print(f"rendered {len(rows)} history rows -> {md}, {html}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
