#!/usr/bin/env python3
"""Append one bench-smoke result to the committed bench history.

Each CI bench-smoke run on the main branch appends a single JSON line
to ``ci/BENCH_history.jsonl`` — commit, mode, and the machine-independent
ratios from every gated section: throughput (``speedup_planned`` /
``speedup_parallel`` plus raw img/s context), single-image latency
(``speedup_tile`` plus ``latency_*`` ms/thread context), the hybrid
scheduler, the autotuner, the global runtime
(``reuse_vs_provision`` / ``concurrent_vs_serial``), and the serving
gateway (``gateway_vs_direct`` / ``fair_p99_ratio`` /
``reap_overhead``). The history
turns ``check_bench.py``'s >20% gate into a *trajectory* check: with
``--history``, the gate compares against the median of the recent
entries instead of a single frozen point, so a slowly-eroding hot path
cannot hide inside the per-commit tolerance.

Usage:
  bench_history.py append FRESH.json HISTORY.jsonl --commit SHA

Idempotent per commit: re-running with a SHA recorded anywhere in the
history is a no-op (CI retries and re-run workflows must not duplicate
rows or reorder the trajectory).
"""

import json
import sys

# Keys copied from the fresh run into the history row, per section.
# The speedup_* ratios are the gated, machine-independent signal; the
# rest is context for reading the trajectory. Latency context keys are
# prefixed so they cannot collide with throughput keys; the gated
# "speedup_tile" ratio keeps its exact name (check_bench.py looks the
# trajectory up by flat key).
RECORDED = {
    "throughput": {
        "speedup_planned": "speedup_planned",
        "speedup_parallel": "speedup_parallel",
        "per_call_img_s": "per_call_img_s",
        "planned_img_s": "planned_img_s",
        "parallel_img_s": "parallel_img_s",
        "threads": "threads",
    },
    "latency": {
        "speedup_tile": "speedup_tile",
        "seq_ms": "latency_seq_ms",
        "tile_ms": "latency_tile_ms",
        "threads": "latency_threads",
    },
    "hybrid": {
        "speedup_pool": "speedup_pool",
        "pool_vs_respawn": "pool_vs_respawn",
        "speedup_hybrid": "speedup_hybrid",
        "pool_ms": "hybrid_pool_ms",
        "respawn_ms": "hybrid_respawn_ms",
        "batch_img_s": "hybrid_batch_img_s",
        "hybrid_img_s": "hybrid_img_s",
        "threads": "hybrid_threads",
    },
    "tuned": {
        "tuned_vs_heuristic": "tuned_vs_heuristic",
        "heuristic_ms": "tuned_heuristic_ms",
        "tuned_ms": "tuned_best_ms",
        "hybrid_cutover": "tuned_hybrid_cutover",
        "threads": "tuned_threads",
    },
    "global": {
        "reuse_vs_provision": "reuse_vs_provision",
        "concurrent_vs_serial": "concurrent_vs_serial",
        "owned_ms": "global_owned_ms",
        "global_ms": "global_best_ms",
        "serial_img_s": "global_serial_img_s",
        "concurrent_img_s": "global_concurrent_img_s",
        "threads": "global_threads",
    },
    "gateway": {
        "gateway_vs_direct": "gateway_vs_direct",
        "fair_p99_ratio": "fair_p99_ratio",
        "reap_overhead": "reap_overhead",
        "direct_ms": "gateway_direct_ms",
        "gateway_ms": "gateway_best_ms",
        "a_p99_us": "gateway_a_p99_us",
        "b_p99_us": "gateway_b_p99_us",
        "reap_enabled_ms": "gateway_reap_enabled_ms",
        "reap_disabled_ms": "gateway_reap_disabled_ms",
        "threads": "gateway_threads",
    },
}


def read_history(path):
    rows = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    rows.append(json.loads(line))
    except FileNotFoundError:
        pass
    return rows


def append(fresh_path, history_path, commit):
    with open(fresh_path) as f:
        fresh = json.load(f)
    thr = fresh.get("throughput", {})
    if not thr:
        print(f"error: {fresh_path} has no throughput object")
        return 2

    rows = read_history(history_path)
    if any(r.get("commit") == commit for r in rows):
        print(f"history already records {commit}; nothing to do")
        return 0

    row = {"commit": commit, "mode": fresh.get("mode", "unknown")}
    for section, keys in RECORDED.items():
        sec = fresh.get(section, {})
        for key, name in keys.items():
            v = sec.get(key)
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                row[name] = round(float(v), 4)
    with open(history_path, "a") as f:
        f.write(json.dumps(row, sort_keys=True) + "\n")
    print(f"recorded {commit} ({len(rows) + 1} entries)")
    return 0


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--")]
    commit = None
    if "--commit" in argv:
        i = argv.index("--commit")
        commit = argv[i + 1] if i + 1 < len(argv) else None
        if commit in args:
            args.remove(commit)
    if len(args) != 3 or args[0] != "append" or not commit:
        print(__doc__)
        return 2
    return append(args[1], args[2], commit)


if __name__ == "__main__":
    sys.exit(main(sys.argv))
