#!/usr/bin/env python3
"""Repo-invariant lint: the conventions the concurrency layer depends on.

Walks ``rust/src`` and fails (exit 1) on violations of five rules that
keep the hand-rolled concurrency auditable. They are *project*
invariants, not general style — each one guards an argument the runtime
or gateway correctness story leans on:

R1  **unsafe-needs-SAFETY** — every ``unsafe`` keyword must have a
    ``SAFETY:`` comment on the same line or within the few lines above
    it. The repo's single transmute is sound only by a multi-step
    protocol argument; that argument must live next to the code.
    (``clippy::undocumented_unsafe_blocks`` is the warn-level second
    line of defense in ``lib.rs``.)

R2  **thread containment** — ``thread::spawn`` / ``thread::scope`` /
    ``thread::Builder`` may appear only under ``runtime/``, in
    ``gateway/dispatch.rs`` (the one dispatcher thread), and under
    ``analysis/`` (the explorer's model threads). "A served request
    spawns zero threads" stays checkable by grep.

R3  **gateway panic hygiene** — no ``.unwrap()`` in non-test gateway
    code, and every ``.expect(`` message must start with
    ``invariant:`` (naming the invariant that makes it infallible).
    Poisoned-lock recovery goes through ``analysis::sync::lock_recover``
    / ``wait_recover``; a panicking dispatcher must never strand a
    blocked ``Ticket::wait`` caller.

R4  **no façade bypass** — ``runtime/global.rs``, ``runtime/pool.rs``
    and everything under ``gateway/`` must take ``Mutex``/``Condvar``
    from ``crate::analysis::sync``, never from ``std::sync`` directly,
    or the interleaving explorer silently loses sight of their yield
    points.

R5  **failpoints never reach release builds** — outside
    ``analysis/failpoint.rs`` itself, any direct call into
    ``analysis::failpoint::`` must sit under a
    ``cfg(... feature = "chaos" ...)`` gate within the few lines above.
    Production sites go through the ``failpoint!`` /
    ``failpoint_shed!`` macros, which carry the gate internally and are
    exempt — the rule catches a hand-written probe that would compile
    fault-injection hooks into a release binary.

Test code (from a ``#[cfg(test)]`` line to end of file, the repo's
test-module convention) is exempt from R2, R3 and R5.

Usage::

    python3 ci/lint_invariants.py              # lint rust/src
    python3 ci/lint_invariants.py --self-test  # prove each rule fires

Stdlib-only, like the other ``ci/*.py`` gates.
"""

from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

# R1: lines above an `unsafe` that may carry the SAFETY tag.
SAFETY_LOOKBACK = 3

# R2: path prefixes (relative to rust/src, "/"-separated) allowed to
# spawn threads.
THREAD_ALLOWED = ("runtime/", "analysis/", "gateway/dispatch.rs")

# R4: files that must import Mutex/Condvar via the analysis::sync
# façade instead of std::sync.
FACADE_FILES = ("runtime/global.rs", "runtime/pool.rs")
FACADE_DIRS = ("gateway/",)

UNSAFE_RE = re.compile(r"\bunsafe\b")
THREAD_RE = re.compile(r"\bthread::(spawn|scope|Builder)\b")
UNWRAP_RE = re.compile(r"\.unwrap\(\)")
EXPECT_RE = re.compile(r'\.expect\(\s*$|\.expect\("')
EXPECT_MSG_RE = re.compile(r'\.expect\(\s*"(?P<msg>[^"]*)')
FACADE_BYPASS_RE = re.compile(
    r"std::sync::(\{[^}]*\b(Mutex|Condvar)\b[^}]*\}|(Mutex|Condvar)\b)"
)
CFG_TEST_RE = re.compile(r"#\[cfg\(test\)\]")

# R5: lines above a direct failpoint call that may carry the chaos cfg
# gate, and the patterns for both. The canonical gate is
# `#[cfg(any(test, feature = "chaos"))]`, so matching on the feature
# token alone accepts every accepted spelling.
CHAOS_LOOKBACK = 3
FAILPOINT_CALL_RE = re.compile(r"\banalysis::failpoint::")
CHAOS_CFG_RE = re.compile(r'cfg\([^)]*feature\s*=\s*"chaos"')


def strip_comment(line: str) -> str:
    """Drop a trailing ``//`` comment (string-literal `//` is rare
    enough in this tree that the approximation is acceptable — and it
    only ever *relaxes* R2/R4, never fakes a violation)."""
    idx = line.find("//")
    return line if idx < 0 else line[:idx]


def test_section_start(lines: list[str]) -> int:
    """Index of the first ``#[cfg(test)]`` line (the repo keeps test
    modules at the bottom of each file), or ``len(lines)``."""
    for i, line in enumerate(lines):
        if CFG_TEST_RE.search(line):
            return i
    return len(lines)


def check_unsafe_safety(rel: str, lines: list[str]) -> list[str]:
    """R1: every `unsafe` needs a `// SAFETY:` comment — on the same
    line, in the contiguous comment block directly above (a multi-line
    SAFETY argument tags its first line), or within the short lookback
    window."""
    problems = []
    for i, line in enumerate(lines):
        code = strip_comment(line)
        if not UNSAFE_RE.search(code):
            continue
        context = lines[max(0, i - SAFETY_LOOKBACK) : i + 1]
        j = i - 1
        while j >= 0 and lines[j].lstrip().startswith("//"):
            context.append(lines[j])
            j -= 1
        if any("SAFETY:" in c for c in context):
            continue
        problems.append(
            f"{rel}:{i + 1}: R1 `unsafe` without a `// SAFETY:` comment "
            f"on the same line or the comment block above"
        )
    return problems


def check_thread_containment(rel: str, lines: list[str]) -> list[str]:
    """R2: thread spawn/scope/Builder only in the allowed locations."""
    if any(
        rel == allowed or rel.startswith(allowed)
        for allowed in THREAD_ALLOWED
    ):
        return []
    problems = []
    cutoff = test_section_start(lines)
    for i, line in enumerate(lines[:cutoff]):
        code = strip_comment(line)
        m = THREAD_RE.search(code)
        if m:
            problems.append(
                f"{rel}:{i + 1}: R2 thread::{m.group(1)} outside "
                f"{THREAD_ALLOWED} — workers belong to the runtime"
            )
    return problems


def check_gateway_hygiene(rel: str, lines: list[str]) -> list[str]:
    """R3: gateway hot path free of `.unwrap()`; `.expect` messages
    must name their invariant."""
    if not rel.startswith("gateway/"):
        return []
    problems = []
    cutoff = test_section_start(lines)
    for i, line in enumerate(lines[:cutoff]):
        code = strip_comment(line)
        if UNWRAP_RE.search(code):
            problems.append(
                f"{rel}:{i + 1}: R3 `.unwrap()` in gateway non-test "
                f"code — use analysis::sync::lock_recover/wait_recover "
                f"or a typed error"
            )
        m = EXPECT_MSG_RE.search(code)
        if m and not m.group("msg").startswith("invariant:"):
            problems.append(
                f"{rel}:{i + 1}: R3 `.expect(\"{m.group('msg')}\")` — "
                f'message must start with "invariant:" naming why it '
                f"cannot fire"
            )
    return problems


def check_facade_bypass(rel: str, lines: list[str]) -> list[str]:
    """R4: façade files must not reach std::sync::{Mutex, Condvar}."""
    in_scope = rel in FACADE_FILES or any(
        rel.startswith(d) for d in FACADE_DIRS
    )
    if not in_scope:
        return []
    problems = []
    for i, line in enumerate(lines):
        code = strip_comment(line)
        if FACADE_BYPASS_RE.search(code):
            problems.append(
                f"{rel}:{i + 1}: R4 direct std::sync Mutex/Condvar in a "
                f"façade file — import from crate::analysis::sync so "
                f"the interleaving explorer sees the yield points"
            )
    return problems


def check_failpoint_gating(rel: str, lines: list[str]) -> list[str]:
    """R5: direct ``analysis::failpoint::`` calls need a chaos cfg gate
    in the lookback window (the failpoint module itself is exempt; the
    self-gating macros never match this pattern)."""
    if rel == "analysis/failpoint.rs":
        return []
    problems = []
    cutoff = test_section_start(lines)
    for i, line in enumerate(lines[:cutoff]):
        code = strip_comment(line)
        if not FAILPOINT_CALL_RE.search(code):
            continue
        context = lines[max(0, i - CHAOS_LOOKBACK) : i + 1]
        if any(CHAOS_CFG_RE.search(c) for c in context):
            continue
        problems.append(
            f"{rel}:{i + 1}: R5 direct analysis::failpoint call without "
            f'a cfg(feature = "chaos") gate above — use the failpoint! / '
            f"failpoint_shed! macros (self-gating) or gate the call, or "
            f"release builds ship fault-injection hooks"
        )
    return problems


CHECKS = (
    check_unsafe_safety,
    check_thread_containment,
    check_gateway_hygiene,
    check_facade_bypass,
    check_failpoint_gating,
)


def lint_tree(root: Path) -> list[str]:
    """Run every check over every .rs file under `root` (rust/src)."""
    problems: list[str] = []
    for path in sorted(root.rglob("*.rs")):
        rel = path.relative_to(root).as_posix()
        lines = path.read_text(encoding="utf-8").splitlines()
        for check in CHECKS:
            problems.extend(check(rel, lines))
    return problems


# --- self-test -------------------------------------------------------
# Synthetic snippets: each rule must fire on its violation and stay
# quiet on the compliant twin. Keeps the gate honest — a regex edit
# that silently stops matching fails CI here, not in production.

SELF_TEST_CASES = [
    (
        "R1 fires on bare unsafe",
        check_unsafe_safety,
        "runtime/x.rs",
        ["let p = unsafe { transmute(q) };"],
        True,
    ),
    (
        "R1 quiet with SAFETY above",
        check_unsafe_safety,
        "runtime/x.rs",
        ["// SAFETY: lifetime erasure only; see the barrier argument.",
         "let p = unsafe { transmute(q) };"],
        False,
    ),
    (
        "R1 quiet with SAFETY atop a long comment block",
        check_unsafe_safety,
        "runtime/x.rs",
        ["// SAFETY: this transmute erases only the lifetime:",
         "// 1. the task is reachable only through queued chunks,",
         "// 2. clones drop before their done count,",
         "// 3. the submitter reclaims after the barrier,",
         "// 4. panics keep the chain intact.",
         "let p = unsafe { transmute(q) };"],
        False,
    ),
    (
        "R1 quiet on unsafe in a comment",
        check_unsafe_safety,
        "runtime/x.rs",
        ["// no unsafe here, just prose"],
        False,
    ),
    (
        "R2 fires outside the allowed set",
        check_thread_containment,
        "dnn/x.rs",
        ["std::thread::spawn(|| {});"],
        True,
    ),
    (
        "R2 quiet under runtime/",
        check_thread_containment,
        "runtime/pool.rs",
        ["std::thread::scope(|s| {});"],
        False,
    ),
    (
        "R2 quiet in a test module",
        check_thread_containment,
        "dnn/x.rs",
        ["#[cfg(test)]", "mod tests {", "std::thread::spawn(|| {});", "}"],
        False,
    ),
    (
        "R3 fires on gateway unwrap",
        check_gateway_hygiene,
        "gateway/dispatch.rs",
        ["let g = shared.state.lock().unwrap();"],
        True,
    ),
    (
        "R3 fires on a non-invariant expect",
        check_gateway_hygiene,
        "gateway/queue.rs",
        ['let x = it.next().expect("non-empty queue");'],
        True,
    ),
    (
        "R3 quiet on an invariant-naming expect",
        check_gateway_hygiene,
        "gateway/queue.rs",
        ['let x = it.next().expect("invariant: non-empty queue");'],
        False,
    ),
    (
        "R3 quiet outside gateway",
        check_gateway_hygiene,
        "runtime/global.rs",
        ["let g = state.lock().unwrap();"],
        False,
    ),
    (
        "R4 fires on a std::sync Mutex import",
        check_facade_bypass,
        "gateway/telemetry.rs",
        ["use std::sync::Mutex;"],
        True,
    ),
    (
        "R4 fires on a braced import",
        check_facade_bypass,
        "runtime/global.rs",
        ["use std::sync::{Arc, Condvar, Mutex};"],
        True,
    ),
    (
        "R4 quiet on Arc-only std::sync",
        check_facade_bypass,
        "gateway/dispatch.rs",
        ["use std::sync::Arc;"],
        False,
    ),
    (
        "R4 quiet outside the façade set",
        check_facade_bypass,
        "coordinator/deploy.rs",
        ["use std::sync::{Arc, Mutex};"],
        False,
    ),
    (
        "R5 fires on an ungated failpoint call",
        check_failpoint_gating,
        "gateway/dispatch.rs",
        ['crate::analysis::failpoint::fire("dispatch::pop");'],
        True,
    ),
    (
        "R5 quiet under the chaos cfg gate",
        check_failpoint_gating,
        "analysis/mod.rs",
        ['#[cfg(any(test, feature = "chaos"))]',
         'crate::analysis::failpoint::fire("dispatch::pop");'],
        False,
    ),
    (
        "R5 quiet on the self-gating macro",
        check_failpoint_gating,
        "gateway/dispatch.rs",
        ['crate::failpoint!("dispatch::pop");'],
        False,
    ),
    (
        "R5 quiet inside the failpoint module itself",
        check_failpoint_gating,
        "analysis/failpoint.rs",
        ['crate::analysis::failpoint::fire("x");'],
        False,
    ),
    (
        "R5 quiet in a test module",
        check_failpoint_gating,
        "gateway/mod.rs",
        ["#[cfg(test)]",
         "mod tests {",
         'crate::analysis::failpoint::fire("x");',
         "}"],
        False,
    ),
]


def self_test() -> int:
    """Exercise every rule on synthetic snippets; exit non-zero if any
    rule fails to fire (or fires spuriously)."""
    failures = 0
    for name, check, rel, lines, should_fire in SELF_TEST_CASES:
        fired = bool(check(rel, lines))
        if fired != should_fire:
            failures += 1
            print(
                f"self-test FAIL: {name} — expected "
                f"{'a finding' if should_fire else 'silence'}, got "
                f"{'a finding' if fired else 'silence'}"
            )
    if failures:
        print(f"lint_invariants self-test: {failures} case(s) failed")
        return 1
    print(f"lint_invariants self-test: {len(SELF_TEST_CASES)} cases ok")
    return 0


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--root",
        default="rust/src",
        help="source tree to lint (default: rust/src)",
    )
    parser.add_argument(
        "--self-test",
        action="store_true",
        help="run the rule self-test instead of linting the tree",
    )
    args = parser.parse_args()
    if args.self_test:
        return self_test()
    root = Path(args.root)
    if not root.is_dir():
        print(f"lint_invariants: no such directory: {root}")
        return 2
    problems = lint_tree(root)
    for p in problems:
        print(p)
    if problems:
        print(f"lint_invariants: {len(problems)} violation(s)")
        return 1
    print("lint_invariants: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
