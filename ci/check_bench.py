#!/usr/bin/env python3
"""Gate bench-smoke on the committed throughput baseline / trajectory.

Compares a freshly produced BENCH json (``cargo bench -- --smoke --json
BENCH_ci.json``) against the committed baseline and fails when any
baseline metric regresses by more than the tolerance (default 20%).

Absolute images/s varies with runner hardware, so the committed baseline
pins *machine-independent ratios* (LayerPlan and worker-pool speedups
over the pre-plan per-call path). Every numeric key present in the
baseline's ``throughput`` object is compared as higher-is-better; keys
present only in the fresh results (e.g. the raw img/s numbers) are
reported for the log but not gated.

With ``--history ci/BENCH_history.jsonl`` the gate becomes a
*trajectory*: once the committed history (appended per main-branch
commit by ``bench_history.py``) holds at least ``MIN_HISTORY`` entries
for a key, the effective baseline is the **median of the last
``HISTORY_WINDOW`` entries** — raised to at least the committed
baseline, so the floor can rise as the hot path improves but never
sinks below the frozen point. A slowly-eroding hot path therefore
cannot hide inside the per-commit tolerance.

``speedup_parallel`` additionally depends on how many cores the runner
actually has: a 2-vCPU runner cannot hit a 4-core baseline. Its
effective baseline is therefore ``min(baseline, 0.75 * threads)`` using
the thread count recorded in the fresh results, so the gate demands
75%-of-ideal pool scaling rather than a fixed machine-dependent number.

Usage: check_bench.py FRESH.json BASELINE.json [--tolerance 0.20]
                      [--history HISTORY.jsonl]
"""

import json
import sys

# Trajectory parameters: how many history entries activate the median
# gate, and how many recent entries the median looks at.
MIN_HISTORY = 3
HISTORY_WINDOW = 5

# Only ratio keys are trajectory-gated; raw img/s is machine-dependent.
TRAJECTORY_KEYS = {"speedup_planned", "speedup_parallel"}


def median(values):
    xs = sorted(values)
    mid = len(xs) // 2
    if len(xs) % 2:
        return xs[mid]
    return (xs[mid - 1] + xs[mid]) / 2.0


def load_history(path):
    rows = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    rows.append(json.loads(line))
    except FileNotFoundError:
        print(f"note: no history at {path}; falling back to the baseline")
    return rows


def trajectory_baseline(history, key, committed):
    """Median of the recent history for `key`, floored at `committed`."""
    values = [
        r[key]
        for r in history[-HISTORY_WINDOW:]
        if isinstance(r.get(key), (int, float))
    ]
    if len(values) < MIN_HISTORY:
        return committed, "baseline"
    return max(median(values), committed), f"median of last {len(values)}"


def main(argv):
    tol = 0.20
    rest = argv[1:]
    if "--tolerance" in rest:
        i = rest.index("--tolerance")
        try:
            tol = float(rest[i + 1])
        except (IndexError, ValueError):
            print("error: --tolerance needs a numeric value")
            return 2
        del rest[i : i + 2]
    history = []
    if "--history" in rest:
        i = rest.index("--history")
        if i + 1 >= len(rest):
            print("error: --history needs a path")
            return 2
        history = load_history(rest[i + 1])
        del rest[i : i + 2]
    args = [a for a in rest if not a.startswith("--")]
    if len(args) != 2:
        print(__doc__)
        return 2

    with open(args[0]) as f:
        fresh = json.load(f)
    with open(args[1]) as f:
        base = json.load(f)

    ft = fresh.get("throughput", {})
    bt = base.get("throughput", {})
    if not bt:
        print(f"error: {args[1]} has no throughput baseline")
        return 2

    failures = []
    threads = ft.get("threads")
    for key in sorted(bt):
        bval = bt[key]
        if not isinstance(bval, (int, float)) or isinstance(bval, bool):
            continue
        source = "baseline"
        if history and key in TRAJECTORY_KEYS:
            bval, source = trajectory_baseline(history, key, bval)
        fval = ft.get(key)
        if not isinstance(fval, (int, float)):
            failures.append(f"{key}: missing from fresh results")
            print(f"  {key:<20} baseline {bval:8.3f}  fresh MISSING  FAIL")
            continue
        if key == "speedup_parallel" and isinstance(threads, (int, float)):
            bval = min(bval, 0.75 * threads)
        floor = (1.0 - tol) * bval
        ok = fval >= floor
        print(
            f"  {key:<20} {source:<17} {bval:8.3f}  fresh {fval:8.3f}  "
            f"floor {floor:8.3f}  {'OK' if ok else 'FAIL'}"
        )
        if not ok:
            failures.append(
                f"{key}: {fval:.3f} is more than {tol:.0%} below the "
                f"baseline {bval:.3f}"
            )

    # informational: ungated fresh metrics
    for key in sorted(ft):
        if key in bt or not isinstance(ft[key], (int, float)):
            continue
        print(f"  {key:<20} (ungated)          fresh {ft[key]:8.3f}")

    if failures:
        print("\nthroughput regression detected:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nthroughput within baseline tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
