#!/usr/bin/env python3
"""Gate bench-smoke on the committed throughput/latency baseline.

Compares a freshly produced BENCH json (``cargo bench -- --smoke --json
BENCH_ci.json``) against the committed baseline and fails when any
baseline metric regresses by more than the tolerance (default 20%).

Gated sections: ``throughput`` (batch serving, images/s), ``latency``
(single-image wall clock, sequential vs the tile-parallel latency
mode), ``hybrid`` (persistent-pool scheduler), ``tuned`` (the
deploy-time autotuner's tuned-vs-heuristic pooled latency, a
same-machine A/B gated >= 1.0), and ``global`` (the process-wide
work-stealing runtime: reuse_vs_provision pins that serving on the
standing worker fleet never loses to provisioning a scoped pool per
call). Absolute images/s and milliseconds vary
with runner hardware, so the committed baseline pins
*machine-independent ratios* (the LayerPlan / worker-pool speedups over
the pre-plan per-call path, and the tile-mode speedup over the
sequential single-image walk). Every numeric key present in a
baseline section is compared as higher-is-better; keys present only in
the fresh results (e.g. raw img/s or ms numbers) are reported for the
log but not gated.

With ``--history ci/BENCH_history.jsonl`` the gate becomes a
*trajectory*: once the committed history (appended per main-branch
commit by ``bench_history.py``) holds at least ``MIN_HISTORY`` entries
for a key, the effective baseline is the **median of the last
``HISTORY_WINDOW`` entries** — raised to at least the committed
baseline, so the floor can rise as the hot path improves but never
sinks below the frozen point. A slowly-eroding hot path therefore
cannot hide inside the per-commit tolerance.

Pool-scaling ratios additionally depend on how many cores the runner
actually has: a 2-vCPU runner cannot hit a 4-core baseline. The
effective baseline of each key in ``THREAD_CAPPED`` is therefore
``min(baseline, factor * threads)`` using the thread count recorded in
that section of the fresh results, so the gate demands a fraction of
ideal scaling rather than a fixed machine-dependent number.

Usage: check_bench.py FRESH.json BASELINE.json [--tolerance 0.20]
                      [--history HISTORY.jsonl]
"""

import json
import sys

# Trajectory parameters: how many history entries activate the median
# gate, and how many recent entries the median looks at.
MIN_HISTORY = 3
HISTORY_WINDOW = 5

# Gated sections of the BENCH json, in report order. "hybrid" is the
# persistent-pool scheduler: speedup_pool (pooled single-image latency
# over the sequential walk) is trajectory-gated next to speedup_tile,
# and pool_vs_respawn pins that the pool never loses to the legacy
# spawn-per-layer tiler at equal thread count. "tuned" is the
# deploy-time autotuner: tuned_vs_heuristic (tuned vs heuristic pooled
# latency, same machine, min-of-N) is gated >= the 1.0 baseline so a
# tuned configuration can never lose to the fixed heuristics it
# replaced. "global" is the process-wide work-stealing runtime:
# reuse_vs_provision (shared-fleet vs per-call-provisioned batch
# latency, same machine, min-of-N) is gated >= the 1.0 baseline so the
# global runtime can never lose to the scoped pools it replaced.
# "gateway" is the admission gateway: gateway_vs_direct (the identical
# 2-tenant workload through the gateway vs direct deployment calls,
# same machine, min-of-N) is gated >= the 0.9 baseline — admission +
# dispatch may never cost more than 10% of the serving path — and
# fair_p99_ratio (min/max of the two tenants' exact p99 latencies
# under interleaved equal-priority load) floors how far one tenant may
# starve the other.
SECTIONS = (
    "throughput",
    "latency",
    "hybrid",
    "tuned",
    "global",
    "gateway",
)

# Only ratio keys are trajectory-gated; raw img/s and ms are
# machine-dependent.
TRAJECTORY_KEYS = {
    "speedup_planned",
    "speedup_parallel",
    "speedup_tile",
    "speedup_pool",
    "tuned_vs_heuristic",
    "reuse_vs_provision",
}

# Ratios whose effective baseline is capped at factor * recorded thread
# count (pool scaling cannot exceed the cores the runner has).
THREAD_CAPPED = {
    "speedup_parallel": 0.75,
    "speedup_tile": 0.75,
    "speedup_pool": 0.75,
}

# Keys gated tighter than the global tolerance. pool_vs_respawn and
# reuse_vs_provision are direct same-machine A/Bs (pooled vs respawn
# tiler, shared fleet vs per-call provisioning — each at equal thread
# count), so machine variance cancels and only run-to-run noise
# remains: neither may *lose* to the path it replaced beyond a 5%
# noise band. gateway_vs_direct already bakes its 10% overhead
# allowance into the committed 0.9 baseline, so it is gated exactly
# (tolerance 0): the floor is the baseline itself. reap_overhead
# (non-reaping vs reaping gateway on a far-deadline workload where
# nothing expires) likewise bakes its 5% allowance into the committed
# 0.95 baseline and is gated exactly — the deadline reaper's sweeps
# and timed wakeups may never cost more than that on deadline-free
# serving.
KEY_TOLERANCE = {
    "pool_vs_respawn": 0.05,
    "reuse_vs_provision": 0.05,
    "gateway_vs_direct": 0.0,
    "reap_overhead": 0.0,
}


def median(values):
    xs = sorted(values)
    mid = len(xs) // 2
    if len(xs) % 2:
        return xs[mid]
    return (xs[mid - 1] + xs[mid]) / 2.0


def load_history(path):
    rows = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    rows.append(json.loads(line))
    except FileNotFoundError:
        print(f"note: no history at {path}; falling back to the baseline")
    return rows


def trajectory_baseline(history, key, committed):
    """Median of the recent history for `key`, floored at `committed`."""
    values = [
        r[key]
        for r in history[-HISTORY_WINDOW:]
        if isinstance(r.get(key), (int, float))
    ]
    if len(values) < MIN_HISTORY:
        return committed, "baseline"
    return max(median(values), committed), f"median of last {len(values)}"


def gate_section(section, fresh_sec, base_sec, history, tol):
    """Compare one section of fresh results against its baseline.

    Returns the list of failure strings (empty = section passes).
    """
    failures = []
    threads = fresh_sec.get("threads")
    for key in sorted(base_sec):
        bval = base_sec[key]
        if not isinstance(bval, (int, float)) or isinstance(bval, bool):
            continue
        source = "baseline"
        if history and key in TRAJECTORY_KEYS:
            bval, source = trajectory_baseline(history, key, bval)
        fval = fresh_sec.get(key)
        if not isinstance(fval, (int, float)):
            failures.append(f"{section}.{key}: missing from fresh results")
            print(f"  {key:<20} {source:<17} {bval:8.3f}  fresh MISSING  FAIL")
            continue
        if key in THREAD_CAPPED and isinstance(threads, (int, float)):
            bval = min(bval, THREAD_CAPPED[key] * threads)
        key_tol = KEY_TOLERANCE.get(key, tol)
        floor = (1.0 - key_tol) * bval
        ok = fval >= floor
        print(
            f"  {key:<20} {source:<17} {bval:8.3f}  fresh {fval:8.3f}  "
            f"floor {floor:8.3f}  {'OK' if ok else 'FAIL'}"
        )
        if not ok:
            failures.append(
                f"{section}.{key}: {fval:.3f} is more than {key_tol:.0%} "
                f"below the baseline {bval:.3f}"
            )

    # informational: ungated fresh metrics
    for key in sorted(fresh_sec):
        if key in base_sec or not isinstance(fresh_sec[key], (int, float)):
            continue
        print(f"  {key:<20} (ungated)          fresh {fresh_sec[key]:8.3f}")
    return failures


def main(argv):
    tol = 0.20
    rest = argv[1:]
    if "--tolerance" in rest:
        i = rest.index("--tolerance")
        try:
            tol = float(rest[i + 1])
        except (IndexError, ValueError):
            print("error: --tolerance needs a numeric value")
            return 2
        del rest[i : i + 2]
    history = []
    if "--history" in rest:
        i = rest.index("--history")
        if i + 1 >= len(rest):
            print("error: --history needs a path")
            return 2
        history = load_history(rest[i + 1])
        del rest[i : i + 2]
    args = [a for a in rest if not a.startswith("--")]
    if len(args) != 2:
        print(__doc__)
        return 2

    with open(args[0]) as f:
        fresh = json.load(f)
    with open(args[1]) as f:
        base = json.load(f)

    if not base.get("throughput"):
        print(f"error: {args[1]} has no throughput baseline")
        return 2

    failures = []
    for section in SECTIONS:
        base_sec = base.get(section, {})
        if not base_sec:
            continue
        print(f"[{section}]")
        failures += gate_section(
            section, fresh.get(section, {}), base_sec, history, tol
        )

    if failures:
        print("\nbench regression detected:")
        for f in failures:
            print(f"  - {f}")
        return 1
    gated = ", ".join(s for s in SECTIONS if base.get(s))
    print(f"\n{gated} within baseline tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
