PYTHON ?= python
ARTIFACTS ?= rust/artifacts

.PHONY: build test pytest artifacts bench bench-smoke clean-artifacts

build:
	cargo build --release

test:
	cargo test -q

pytest:
	$(PYTHON) -m pytest python/tests -q

# Lower every DNN layer to an HLO-text artifact + manifest (only needed
# for the PJRT backend; the native backend ships the same zoo built in).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(ARTIFACTS)

bench:
	cargo bench --bench paper_benches

bench-smoke:
	cargo bench --bench paper_benches -- --smoke --json BENCH_ci.json

clean-artifacts:
	rm -rf $(ARTIFACTS)
