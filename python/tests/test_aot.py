"""AOT pipeline tests: artifact-spec gathering is pure python (always
runs); the actual Pallas/StableHLO lowering is exercised as a smoke test
that skips gracefully on jax builds that cannot lower (CPU-only wheels
with mismatched xla_client internals, missing pallas, etc.)."""

import pytest

pytest.importorskip("jax", reason="jax not installed")

from compile import aot, model


def test_gather_specs_covers_both_configs_plus_quickstart():
    specs = aot.gather_specs(["uniform8", "mixed"])
    names = set(specs)
    for cfg in ("uniform8", "mixed"):
        for spec in model.resnet20_layers(cfg):
            assert spec.artifact() in names
    qs = aot.quickstart_spec()
    assert qs.artifact() in names
    assert specs[qs.artifact()].shift == 10


def test_manifest_entry_round_trips_layer_signature():
    spec = aot.quickstart_spec()
    _, shapes = model.layer_fn(spec)
    entry = aot.manifest_entry(spec.artifact(), spec, shapes)
    assert entry["op"] == "conv3x3"
    assert (entry["h"], entry["cin"], entry["cout"]) == (16, 32, 32)
    assert entry["shift"] == 10
    assert entry["arg_shapes"][0] == [18, 18, 32]  # padded plane


def test_quickstart_artifact_lowers_to_hlo_text():
    spec = aot.quickstart_spec()
    fn, shapes = model.layer_fn(spec)
    try:
        text = aot.to_hlo_text(fn, shapes)
    except Exception as e:
        pytest.skip(f"Pallas-AOT lowering unavailable on this jax build: {e}")
    assert "HloModule" in text
    # four parameters: activation, weights, scale, bias
    assert text.count("parameter") >= 4
