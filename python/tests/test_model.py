"""L2 model tests: ResNet-20 layer schedule structure + end-to-end forward."""

import numpy as np
import pytest

from compile import model
from compile.model import LayerSpec


@pytest.mark.parametrize("config", ["uniform8", "mixed"])
def test_layer_count(config):
    layers = model.resnet20_layers(config)
    convs = [l for l in layers if l.op == "conv3x3"]
    # ResNet-20 = stem + 18 3x3 convs (+2 1x1 downsamples not counted).
    assert len(convs) == 19
    assert sum(1 for l in layers if l.op == "conv1x1") == 2
    assert sum(1 for l in layers if l.op == "add") == 9
    assert layers[-1].op == "linear"
    assert layers[-2].op == "avgpool"


def test_shapes_chain():
    """Each layer's input shape must match the previous producer's output."""
    layers = model.resnet20_layers("uniform8")
    cur_h, cur_c = 32, 3
    for l in layers:
        if l.op in ("conv3x3", "conv1x1"):
            if l.op == "conv3x3":
                assert l.h == cur_h or l.name.endswith(".down"), l
            if not l.name.endswith(".down"):
                assert l.cin == cur_c, l
                cur_h, cur_c = l.h_out, l.cout
        elif l.op == "add":
            assert (l.h, l.cin) == (cur_h, cur_c), l
        elif l.op == "avgpool":
            assert (l.h, l.cin) == (cur_h, cur_c)
            cur_h = 1
        elif l.op == "linear":
            assert l.cin == cur_c


def test_mixed_precisions_follow_hawq():
    layers = model.resnet20_layers("mixed")
    wbits = {l.w_bits for l in layers if l.op.startswith("conv")}
    assert wbits <= {2, 3, 6, 8}
    ibits = {l.i_bits for l in layers if l.op.startswith("conv")}
    assert ibits <= {4, 8}


def test_artifact_names_unique_per_shape():
    layers = model.resnet20_layers("uniform8")
    # Repeated residual blocks share artifacts -- that's the point.
    names = {l.artifact() for l in layers}
    assert len(names) < len(layers)
    for n in names:
        assert " " not in n and "/" not in n


@pytest.mark.parametrize("config", ["uniform8", "mixed"])
def test_forward_runs_and_is_deterministic(config):
    layers = model.resnet20_layers(config)
    rng = np.random.default_rng(42)
    params = {l.name: model.random_params(l, rng)
              for l in layers if l.op in ("conv3x3", "conv1x1", "linear")}
    image = rng.integers(0, 1 << layers[0].i_bits,
                         (32, 32, 3)).astype(np.int32)
    out1 = model.resnet20_forward(layers, params, image)
    out2 = model.resnet20_forward(layers, params, image)
    assert out1.shape == (10,)
    np.testing.assert_array_equal(out1, out2)
    assert out1.min() >= 0  # final layer output is O-bit unsigned


def test_forward_layerwise_matches_ref_oracle():
    """Compose the numpy oracle layer-by-layer and compare with the jax
    model -- validates the schedule semantics end to end."""
    from compile.kernels import ref

    layers = model.resnet20_layers("mixed")
    rng = np.random.default_rng(0)
    params = {l.name: model.random_params(l, rng)
              for l in layers if l.op in ("conv3x3", "conv1x1", "linear")}
    image = rng.integers(0, 16, (32, 32, 3)).astype(np.int32)

    cur = image
    block_in = cur
    down_out = None
    for spec in layers:
        if spec.op == "conv3x3":
            if spec.name.endswith(".conv0"):
                block_in = cur
            w, s, b = params[spec.name]
            x = np.pad(cur, ((1, 1), (1, 1), (0, 0)))
            cur = ref.conv3x3_ref(x, w, s, b, o_bits=spec.o_bits,
                                  shift=spec.shift, stride=spec.stride)
        elif spec.op == "conv1x1":
            w, s, b = params[spec.name]
            down_out = ref.conv1x1_ref(block_in, w, s, b,
                                       o_bits=spec.o_bits, shift=spec.shift,
                                       stride=spec.stride)
        elif spec.op == "add":
            short = block_in if spec.residual_of == "input" else down_out
            cur = ref.add_requant_ref(cur, short, scale_a=1, scale_b=1,
                                      shift=spec.shift, o_bits=spec.o_bits)
        elif spec.op == "avgpool":
            cur = ref.avgpool_ref(cur, shift=6)
        elif spec.op == "linear":
            w, s, b = params[spec.name]
            cur = ref.linear_ref(cur, w, s, b, o_bits=spec.o_bits,
                                 shift=spec.shift)

    got = model.resnet20_forward(layers, params, image)
    np.testing.assert_array_equal(got, cur)
