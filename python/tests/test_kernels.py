"""L1 correctness: Pallas bit-serial kernels vs the pure-numpy oracle.

Bit-exact equality is required (integer datapath), across shapes, strides
and every 2..8-bit precision combination -- hypothesis drives the sweep.
"""

import numpy as np
import pytest

# The hypothesis sweep is the richest check but must not hard-fail the
# suite on minimal environments: skip the module cleanly if absent.
pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import rbe_conv as k

RNG = np.random.default_rng(1234)


def rand_inputs(h, kin, kout, w_bits, i_bits, taps3x3, rng=RNG):
    hp = h + 2 if taps3x3 else h
    x = rng.integers(0, 1 << i_bits, (hp, hp, kin)).astype(np.int32)
    wshape = (kout, kin, 3, 3) if taps3x3 else (kout, kin)
    w = rng.integers(-(1 << (w_bits - 1)), 1 << (w_bits - 1),
                     wshape).astype(np.int32)
    scale = rng.integers(1, 32, (kout,)).astype(np.int32)
    bias = rng.integers(-1000, 1000, (kout,)).astype(np.int32)
    return x, w, scale, bias


bits = st.integers(min_value=2, max_value=8)


@settings(max_examples=20, deadline=None)
@given(w_bits=bits, i_bits=bits, o_bits=bits,
       h=st.sampled_from([4, 6, 8]),
       kin=st.sampled_from([3, 8, 16]),
       kout=st.sampled_from([4, 16]),
       stride=st.sampled_from([1, 2]),
       shift=st.integers(min_value=0, max_value=16))
def test_conv3x3_matches_ref(w_bits, i_bits, o_bits, h, kin, kout, stride,
                             shift):
    x, w, scale, bias = rand_inputs(h, kin, kout, w_bits, i_bits, True)
    got = np.asarray(k.rbe_conv3x3(x, w, scale, bias, w_bits=w_bits,
                                   i_bits=i_bits, o_bits=o_bits,
                                   shift=shift, stride=stride))
    want = ref.conv3x3_ref(x, w, scale, bias, o_bits=o_bits, shift=shift,
                           stride=stride)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(w_bits=bits, i_bits=bits, o_bits=bits,
       h=st.sampled_from([4, 8]),
       kin=st.sampled_from([8, 16, 32]),
       kout=st.sampled_from([8, 32]),
       stride=st.sampled_from([1, 2]),
       shift=st.integers(min_value=0, max_value=16))
def test_conv1x1_matches_ref(w_bits, i_bits, o_bits, h, kin, kout, stride,
                             shift):
    x, w, scale, bias = rand_inputs(h, kin, kout, w_bits, i_bits, False)
    got = np.asarray(k.rbe_conv1x1(x, w, scale, bias, w_bits=w_bits,
                                   i_bits=i_bits, o_bits=o_bits,
                                   shift=shift, stride=stride))
    want = ref.conv1x1_ref(x, w, scale, bias, o_bits=o_bits, shift=shift,
                           stride=stride)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(w_bits=bits, i_bits=bits, o_bits=bits,
       kin=st.sampled_from([16, 64]),
       kout=st.sampled_from([10, 32]),
       shift=st.integers(min_value=0, max_value=12))
def test_linear_matches_ref(w_bits, i_bits, o_bits, kin, kout, shift):
    rng = np.random.default_rng(7)
    x = rng.integers(0, 1 << i_bits, (kin,)).astype(np.int32)
    w = rng.integers(-(1 << (w_bits - 1)), 1 << (w_bits - 1),
                     (kout, kin)).astype(np.int32)
    scale = rng.integers(1, 32, (kout,)).astype(np.int32)
    bias = rng.integers(-1000, 1000, (kout,)).astype(np.int32)
    got = np.asarray(k.rbe_linear(x, w, scale, bias, w_bits=w_bits,
                                  i_bits=i_bits, o_bits=o_bits, shift=shift))
    want = ref.linear_ref(x, w, scale, bias, o_bits=o_bits, shift=shift)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(o_bits=bits, shift=st.integers(min_value=0, max_value=8),
       h=st.sampled_from([4, 8]), ch=st.sampled_from([8, 32]))
def test_add_requant_matches_ref(o_bits, shift, h, ch):
    rng = np.random.default_rng(9)
    a = rng.integers(0, 256, (h, h, ch)).astype(np.int32)
    b = rng.integers(0, 256, (h, h, ch)).astype(np.int32)
    got = np.asarray(k.add_requant(a, b, scale_a=1, scale_b=1, shift=shift,
                                   o_bits=o_bits))
    want = ref.add_requant_ref(a, b, scale_a=1, scale_b=1, shift=shift,
                               o_bits=o_bits)
    np.testing.assert_array_equal(got, want)


def test_avgpool_matches_ref():
    rng = np.random.default_rng(3)
    x = rng.integers(0, 256, (8, 8, 64)).astype(np.int32)
    got = np.asarray(k.avgpool_quant(x, shift=6))
    np.testing.assert_array_equal(got, ref.avgpool_ref(x, shift=6))


def test_weight_msb_is_negative():
    """Two's-complement bit weighting: w = -4 at 3 bits must contribute -4."""
    x = np.ones((1, 1, 1), dtype=np.int32)
    w = np.full((1, 1), -4, dtype=np.int32)
    scale = np.ones(1, dtype=np.int32)
    bias = np.full((1,), 100, dtype=np.int32)
    out = np.asarray(k.rbe_conv1x1(x, w, scale, bias, w_bits=3, i_bits=1,
                                   o_bits=8, shift=0))
    assert out.flatten()[0] == 96  # 100 + (-4)


def test_relu_clipping():
    """Eq. 2 clips to [0, 2^O - 1] -- negative accumulations become 0."""
    x = np.full((1, 1, 4), 3, dtype=np.int32)
    w = np.full((1, 4), -2, dtype=np.int32)
    scale = np.ones(1, dtype=np.int32)
    bias = np.zeros(1, dtype=np.int32)
    out = np.asarray(k.rbe_conv1x1(x, w, scale, bias, w_bits=3, i_bits=2,
                                   o_bits=4, shift=0))
    assert out.flatten()[0] == 0


def test_output_saturation():
    x = np.full((1, 1, 8), 255, dtype=np.int32)
    w = np.full((1, 8), 127, dtype=np.int32)
    scale = np.ones(1, dtype=np.int32)
    bias = np.zeros(1, dtype=np.int32)
    out = np.asarray(k.rbe_conv1x1(x, w, scale, bias, w_bits=8, i_bits=8,
                                   o_bits=4, shift=0))
    assert out.flatten()[0] == 15
