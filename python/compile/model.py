"""L2: quantized DNN layer graphs (paper SS IV) built on the L1 kernels.

Defines the ResNet-20/CIFAR-10 network the paper deploys (Figs. 17-18),
in two precision configurations:

* ``uniform8`` -- every tensor 8-bit (the paper's "8-bit" baseline);
* ``mixed``    -- a representative HAWQ assignment (weights in {2,3,6,8}
  bits, activations in {4,8} bits) following SS IV: sensitive first/last
  layers keep 8-bit weights, inner stages drop to 6/3/2 bits.

The layer list here is the **single source of truth for artifact names**:
`aot.py` lowers one PJRT artifact per unique (op, shape, precision) tuple
using `artifact_name()`, and the rust `dnn` module re-derives the same
names when scheduling layers (validated by rust integration tests against
`artifacts/manifest.json`).

Functional weights are randomly initialized: the paper's latency/energy
results (the ones we reproduce) depend only on shapes, precisions and
tiling, not on learned values -- see DESIGN.md substitution table.
"""

import dataclasses
import math
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from .kernels import rbe_conv as k


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One schedulable layer. `h` is the *unpadded* input spatial size."""
    op: str                  # conv3x3 | conv1x1 | add | avgpool | linear
    name: str                # human-readable position in the network
    h: int                   # input spatial size (square); 0 for linear
    cin: int
    cout: int
    stride: int = 1
    w_bits: int = 8
    i_bits: int = 8
    o_bits: int = 8
    shift: int = 0           # normquant right-shift (Eq. 2)
    residual_of: Optional[str] = None  # for `add`: name of shortcut source

    @property
    def h_out(self) -> int:
        return (self.h + self.stride - 1) // self.stride if self.h else 0

    def artifact(self) -> str:
        return artifact_name(self)


def artifact_name(s: LayerSpec) -> str:
    """Stable artifact naming shared with rust (`dnn::layer::artifact_name`)."""
    if s.op in ("conv3x3", "conv1x1"):
        return (f"{s.op}_h{s.h}_ci{s.cin}_co{s.cout}_s{s.stride}"
                f"_w{s.w_bits}i{s.i_bits}o{s.o_bits}")
    if s.op == "add":
        return f"add_h{s.h}_k{s.cin}_o{s.o_bits}_sh{s.shift}"
    if s.op == "avgpool":
        return f"avgpool_h{s.h}_k{s.cin}"
    if s.op == "linear":
        return f"linear_ci{s.cin}_co{s.cout}_w{s.w_bits}i{s.i_bits}o{s.o_bits}"
    raise ValueError(f"unknown op {s.op}")


# Per-stage precision assignment: (w_bits, i_bits, o_bits) for convs.
PRECISIONS = {
    "uniform8": {
        "stem": (8, 8, 8), "stage1": (8, 8, 8), "stage2": (8, 8, 8),
        "stage3": (8, 8, 8), "down": (8, 8, 8), "fc": (8, 8, 8),
    },
    # Representative HAWQ (Dong et al.) mixed assignment per SS IV:
    # weights {2,3,6,8}-bit, activations {4,8}-bit.
    "mixed": {
        "stem": (8, 8, 4), "stage1": (6, 4, 4), "stage2": (3, 4, 4),
        "stage3": (2, 4, 4), "down": (8, 4, 4), "fc": (8, 4, 8),
    },
}


def _shift_for(cin: int, w_bits: int, i_bits: int, o_bits: int,
               taps: int) -> int:
    """normquant shift keeping random-weight outputs in-range (value-level
    behaviour does not affect timing; this keeps the pipeline
    non-degenerate).

    Variance model: acc of N=cin*taps products of U[0,2^i) activations and
    U[-2^(w-1),2^(w-1)) weights has sigma ~ sqrt(N)*2^(w+i-1)*0.335; after
    the ~2^3 mean scale, shifting by `shift` should leave sigma ~ 2^(o-2)
    so ReLU keeps half the mass spread over the output range. Must stay
    numerically identical to rust `dnn::layer::shift_for`.
    """
    x = (0.5 * math.log2(max(cin * taps, 1)) + w_bits + i_bits + 0.42
         - o_bits)
    return max(int(x + 0.5), 0)


def resnet20_layers(config: str = "uniform8") -> List[LayerSpec]:
    """The 3x{3-block} CIFAR ResNet-20 layer schedule, in execution order."""
    p = PRECISIONS[config]
    layers: List[LayerSpec] = []

    def conv(op, name, h, cin, cout, stride, bits):
        w, i, o = bits
        layers.append(LayerSpec(
            op=op, name=name, h=h, cin=cin, cout=cout, stride=stride,
            w_bits=w, i_bits=i, o_bits=o,
            shift=_shift_for(cin, w, i, o, 9 if op == "conv3x3" else 1)))

    # Stem: 3 -> 16 channels at 32x32.
    conv("conv3x3", "stem", 32, 3, 16, 1, p["stem"])

    specs = [("stage1", 32, 16, 16), ("stage2", 16, 16, 32),
             ("stage3", 8, 32, 64)]
    for stage, h_out, cin_stage, ch in specs:
        bits = p[stage]
        for blk in range(3):
            first = blk == 0 and stage != "stage1"
            h_in = h_out * 2 if first else h_out
            cin = cin_stage if blk == 0 else ch
            stride = 2 if first else 1
            conv("conv3x3", f"{stage}.b{blk}.conv0", h_in, cin, ch, stride,
                 bits)
            conv("conv3x3", f"{stage}.b{blk}.conv1", h_out, ch, ch, 1, bits)
            if first:
                conv("conv1x1", f"{stage}.b{blk}.down", h_in, cin, ch, 2,
                     p["down"])
                shortcut = f"{stage}.b{blk}.down"
            else:
                shortcut = "input"
            layers.append(LayerSpec(op="add", name=f"{stage}.b{blk}.add",
                                    h=h_out, cin=ch, cout=ch,
                                    o_bits=bits[2], shift=1,
                                    residual_of=shortcut))

    layers.append(LayerSpec(op="avgpool", name="avgpool", h=8, cin=64,
                            cout=64, shift=6))
    w, i, o = p["fc"]
    layers.append(LayerSpec(op="linear", name="fc", h=0, cin=64, cout=10,
                            w_bits=w, i_bits=i, o_bits=o,
                            shift=_shift_for(64, w, i, o, 1)))
    return layers


def layer_fn(spec: LayerSpec):
    """Build the jax function implementing `spec` (the unit `aot.py` lowers).

    Returns (fn, example_arg_shapes); fn returns a 1-tuple so the lowered
    HLO has a tuple root (matching `return_tuple=True` on the rust side).
    """
    if spec.op == "conv3x3":
        hp = spec.h + 2  # pad=1
        def fn(x, w, scale, bias):
            return (k.rbe_conv3x3(x, w, scale, bias, w_bits=spec.w_bits,
                                  i_bits=spec.i_bits, o_bits=spec.o_bits,
                                  shift=spec.shift, stride=spec.stride),)
        shapes = [(hp, hp, spec.cin), (spec.cout, spec.cin, 3, 3),
                  (spec.cout,), (spec.cout,)]
    elif spec.op == "conv1x1":
        def fn(x, w, scale, bias):
            return (k.rbe_conv1x1(x, w, scale, bias, w_bits=spec.w_bits,
                                  i_bits=spec.i_bits, o_bits=spec.o_bits,
                                  shift=spec.shift, stride=spec.stride),)
        shapes = [(spec.h, spec.h, spec.cin), (spec.cout, spec.cin),
                  (spec.cout,), (spec.cout,)]
    elif spec.op == "add":
        def fn(a, b):
            return (k.add_requant(a, b, scale_a=1, scale_b=1,
                                  shift=spec.shift, o_bits=spec.o_bits),)
        shapes = [(spec.h, spec.h, spec.cin)] * 2
    elif spec.op == "avgpool":
        def fn(x):
            return (k.avgpool_quant(x, shift=6),)
        shapes = [(spec.h, spec.h, spec.cin)]
    elif spec.op == "linear":
        def fn(x, w, scale, bias):
            return (k.rbe_linear(x, w, scale, bias, w_bits=spec.w_bits,
                                 i_bits=spec.i_bits, o_bits=spec.o_bits,
                                 shift=spec.shift),)
        shapes = [(spec.cin,), (spec.cout, spec.cin), (spec.cout,),
                  (spec.cout,)]
    else:
        raise ValueError(spec.op)
    return fn, shapes


def random_params(spec: LayerSpec, rng: np.random.Generator):
    """Random quantized weights/scale/bias for `spec` (numpy int32)."""
    lo = -(1 << (spec.w_bits - 1))
    hi = (1 << (spec.w_bits - 1))
    if spec.op == "conv3x3":
        w = rng.integers(lo, hi, (spec.cout, spec.cin, 3, 3))
    elif spec.op in ("conv1x1", "linear"):
        w = rng.integers(lo, hi, (spec.cout, spec.cin))
    else:
        return None
    scale = rng.integers(1, 16, (spec.cout,))
    bias = rng.integers(-(1 << 10), 1 << 10, (spec.cout,))
    return (w.astype(np.int32), scale.astype(np.int32),
            bias.astype(np.int32))


def resnet20_forward(layers: List[LayerSpec], params: dict,
                     image: np.ndarray) -> np.ndarray:
    """Run the full network in jax (layer-by-layer, same order rust uses).

    `params[name] = (w, scale, bias)`; `image` is (32, 32, 3) int32.
    Returns the (10,) logit vector.  Python tests use this to validate the
    schedule composes; the rust coordinator performs the same composition
    through the AOT artifacts, and the two must agree bit-exactly.
    """
    cur = jnp.asarray(image, dtype=jnp.int32)
    block_in = cur
    down_out = None
    for spec in layers:
        if spec.op == "conv3x3":
            if spec.name.endswith(".conv0"):
                block_in = cur
            w, s, b = map(jnp.asarray, params[spec.name])
            x = jnp.pad(cur, ((1, 1), (1, 1), (0, 0)))
            cur = k.rbe_conv3x3(x, w, s, b, w_bits=spec.w_bits,
                                i_bits=spec.i_bits, o_bits=spec.o_bits,
                                shift=spec.shift, stride=spec.stride)
        elif spec.op == "conv1x1":
            w, s, b = map(jnp.asarray, params[spec.name])
            down_out = k.rbe_conv1x1(block_in, w, s, b, w_bits=spec.w_bits,
                                     i_bits=spec.i_bits, o_bits=spec.o_bits,
                                     shift=spec.shift, stride=spec.stride)
        elif spec.op == "add":
            short = block_in if spec.residual_of == "input" else down_out
            cur = k.add_requant(cur, short, scale_a=1, scale_b=1,
                                shift=spec.shift, o_bits=spec.o_bits)
        elif spec.op == "avgpool":
            cur = k.avgpool_quant(cur, shift=6)
        elif spec.op == "linear":
            w, s, b = map(jnp.asarray, params[spec.name])
            cur = k.rbe_linear(cur, w, s, b, w_bits=spec.w_bits,
                               i_bits=spec.i_bits, o_bits=spec.o_bits,
                               shift=spec.shift)
    return np.asarray(cur)
