"""AOT compilation driver: lower every L2 layer graph to an HLO-text
artifact the rust runtime loads via PJRT.

Interchange format is HLO *text*, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the `xla` rust crate) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONCE, at build time (`make artifacts`); the rust binary is
self-contained afterwards.

Outputs into --out-dir (default ../artifacts):
  <name>.hlo.txt   one per unique (op, shape, precision) tuple across all
                   network configs, plus the quickstart demo artifact
  manifest.json    contract consumed by the rust `dnn`/`runtime` modules:
                   op, shapes, precisions, shift, and argument order per
                   artifact
"""

import argparse
import json
import pathlib
import sys

import jax

from . import model
from .model import LayerSpec


def to_hlo_text(fn, arg_shapes) -> str:
    """jit-lower `fn` for int32 args of `arg_shapes` and emit HLO text."""
    import jax.numpy as jnp
    from jax._src.lib import xla_client as xc

    specs = [jax.ShapeDtypeStruct(s, jnp.int32) for s in arg_shapes]
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def quickstart_spec() -> LayerSpec:
    """Small standalone conv used by examples/quickstart.rs."""
    return LayerSpec(op="conv3x3", name="quickstart", h=16, cin=32, cout=32,
                     stride=1, w_bits=4, i_bits=4, o_bits=4, shift=10)


def gather_specs(configs) -> dict:
    """Unique artifact name -> LayerSpec over all requested configs."""
    specs = {}
    for cfg in configs:
        for spec in model.resnet20_layers(cfg):
            specs.setdefault(spec.artifact(), spec)
    qs = quickstart_spec()
    specs.setdefault(qs.artifact(), qs)
    return specs


def manifest_entry(name: str, spec: LayerSpec, arg_shapes) -> dict:
    return {
        "name": name,
        "op": spec.op,
        "h": spec.h,
        "cin": spec.cin,
        "cout": spec.cout,
        "stride": spec.stride,
        "w_bits": spec.w_bits,
        "i_bits": spec.i_bits,
        "o_bits": spec.o_bits,
        "shift": spec.shift,
        "arg_shapes": [list(s) for s in arg_shapes],
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=None,
                    help="artifact output directory (default: ../artifacts)")
    ap.add_argument("--out", default=None,
                    help="(compat) single-file target; triggers full build "
                         "into its directory")
    ap.add_argument("--configs", nargs="*",
                    choices=sorted(model.PRECISIONS),
                    default=["uniform8", "mixed"])
    ap.add_argument("--only", default=None,
                    help="only build the artifact with this name")
    args = ap.parse_args()

    if args.out_dir:
        out_dir = pathlib.Path(args.out_dir)
    elif args.out:
        out_dir = pathlib.Path(args.out).parent
    else:
        out_dir = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    out_dir.mkdir(parents=True, exist_ok=True)

    specs = gather_specs(args.configs)
    manifest = []
    for name, spec in sorted(specs.items()):
        fn, shapes = model.layer_fn(spec)
        manifest.append(manifest_entry(name, spec, shapes))
        if args.only and name != args.only:
            continue
        path = out_dir / f"{name}.hlo.txt"
        text = to_hlo_text(fn, shapes)
        path.write_text(text)
        print(f"  {name}: {len(text)} chars", flush=True)

    (out_dir / "manifest.json").write_text(
        json.dumps({"artifacts": manifest}, indent=1))
    # Rust-side contract: no JSON dependency is vendored in the build
    # environment, so the runtime parses this TSV twin instead.
    rows = ["name\top\th\tcin\tcout\tstride\tw_bits\ti_bits\to_bits\tshift"]
    for m in manifest:
        rows.append("\t".join(str(m[k]) for k in
                              ("name", "op", "h", "cin", "cout", "stride",
                               "w_bits", "i_bits", "o_bits", "shift")))
    (out_dir / "manifest.tsv").write_text("\n".join(rows) + "\n")
    # Sentinel consumed by the Makefile dependency check.
    (out_dir / "model.hlo.txt").write_text(
        "# sentinel: see manifest.json for the real artifact list\n"
        + json.dumps([m["name"] for m in manifest]))
    print(f"wrote {len(manifest)} artifacts + manifest to {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
