"""L1 Pallas kernels: the RBE datapath (paper SS II-B, Eqs. 1-2).

Each kernel computes a quantized convolution exactly the way the RBE
hardware does:

  1. decompose the unsigned I-bit activations and signed W-bit weights into
     single-bit planes (`bitserial.py`);
  2. form all binary dot-products between planes -- in hardware these are
     the 32-wide AND+popcount BinConv units; here they are a single integer
     einsum over the channel (and filter-tap) dimensions, summing 0/1
     products;
  3. recombine the (W x I) partial planes with +/-2^(i+j) shift
     coefficients into the 32-bit accumulator (Eq. 1, two's-complement MSB
     negative);
  4. normalize/quantize with per-channel scale+bias, arithmetic right shift
     and ReLU clipping to O bits (Eq. 2, the per-Core Quantizer).

Kernels are lowered with ``interpret=True``: on CPU-PJRT a real Mosaic
lowering cannot run, and the interpret path emits plain HLO integer ops the
rust runtime executes bit-exactly.  See DESIGN.md SSTPU-mapping for how the
same kernel tiles onto a real TPU (bit-plane einsum on the MXU, 5x5x32
patches in VMEM standing in for the RBE input buffer).

All tensors are int32 (the simulator's unpacked representation of the
chip's packed 2-8 bit streams); accumulation is int32 like the RBE Accums,
and the normquant product is widened to int64 before the shift, matching a
>32-bit quantizer multiply datapath.
"""

import functools

import jax

# The normquant multiply (Eq. 2) is wider than 32 bits; the artifacts carry
# s64 intermediates, which XLA:CPU executes natively.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bitserial import (bit_coefficients, normquant, unsigned_bitplanes,
                        weight_bitplanes)

__all__ = ["rbe_conv3x3", "rbe_conv1x1", "rbe_linear", "add_requant",
           "avgpool_quant"]


def _recombine(part: jnp.ndarray, w_bits: int, i_bits: int) -> jnp.ndarray:
    """Eq. 1 shift-add reassociation: acc = sum_{i,j} (+/-)2^(i+j) part[i,j].

    Coefficients are compile-time python ints (pallas kernels may not
    capture constant arrays), mirroring the RBE's static shifters.
    """
    coef = bit_coefficients(w_bits, i_bits)
    acc = jnp.zeros(part.shape[2:], dtype=jnp.int32)
    for i in range(w_bits):
        for j in range(i_bits):
            acc = acc + part[i, j] * jnp.int32(coef[i, j])
    return acc


def _conv3x3_kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, *,
                    w_bits, i_bits, o_bits, shift, stride):
    """x: (H+2, W+2, Kin) unsigned; w: (Kout, Kin, 3, 3) signed;
    o: (Ho, Wo, Kout)."""
    x = x_ref[...]
    w = w_ref[...]
    ho, wo, kout = o_ref.shape
    kin = x.shape[2]

    x_b = unsigned_bitplanes(x, i_bits)          # (I, H+2, W+2, Kin)
    w_b = weight_bitplanes(w, w_bits)            # (W, Kout, Kin, 3, 3)

    # Gather the 9 filter-tap views of the input bit planes; each view is
    # the stream one RBE Block consumes (one tap across 32-channel groups).
    taps = []
    for fy in range(3):
        for fx in range(3):
            v = jax.lax.slice(
                x_b,
                (0, fy, fx, 0),
                (i_bits, fy + (ho - 1) * stride + 1,
                 fx + (wo - 1) * stride + 1, kin),
                (1, stride, stride, 1))
            taps.append(v)                        # (I, Ho, Wo, Kin)
    patches = jnp.stack(taps, axis=0)            # (9, I, Ho, Wo, Kin)

    wt = jnp.transpose(w_b.reshape(w_bits, kout, kin, 9), (3, 0, 1, 2))

    # Binary-domain dot products: contract filter taps (t) and channels (c)
    # for every (weight-bit i, input-bit j) pair -- the BinConv AND arrays.
    part = jnp.einsum("tjhwc,tikc->ijhwk", patches, wt,
                      preferred_element_type=jnp.int32)

    acc = _recombine(part, w_bits, i_bits)

    scale = scale_ref[...].astype(jnp.int64)
    bias = bias_ref[...].astype(jnp.int64)
    out = normquant(acc.astype(jnp.int64), scale[None, None, :],
                    bias[None, None, :], shift, o_bits)
    o_ref[...] = out.astype(jnp.int32)


def _conv1x1_kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, *,
                    w_bits, i_bits, o_bits, shift, stride):
    """x: (H, W, Kin) unsigned; w: (Kout, Kin) signed; o: (Ho, Wo, Kout).

    In 1x1 mode the RBE maps the W weight bits bit-parallel across the
    Blocks of each Core; arithmetically this is the same plane einsum
    without the tap dimension.
    """
    x = x_ref[...]
    w = w_ref[...]
    ho, wo, kout = o_ref.shape
    kin = x.shape[2]

    if stride != 1:
        x = jax.lax.slice(x, (0, 0, 0),
                          ((ho - 1) * stride + 1, (wo - 1) * stride + 1, kin),
                          (stride, stride, 1))

    x_b = unsigned_bitplanes(x, i_bits)          # (I, Ho, Wo, Kin)
    w_b = weight_bitplanes(w, w_bits)            # (W, Kout, Kin)

    part = jnp.einsum("jhwc,ikc->ijhwk", x_b, w_b,
                      preferred_element_type=jnp.int32)
    acc = _recombine(part, w_bits, i_bits)

    scale = scale_ref[...].astype(jnp.int64)
    bias = bias_ref[...].astype(jnp.int64)
    out = normquant(acc.astype(jnp.int64), scale[None, None, :],
                    bias[None, None, :], shift, o_bits)
    o_ref[...] = out.astype(jnp.int32)


def _linear_kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, *,
                   w_bits, i_bits, o_bits, shift):
    """Fully-connected as the RBE's 1x1 corner case: x (Kin,), w (Kout, Kin)."""
    x = x_ref[...]
    w = w_ref[...]
    x_b = unsigned_bitplanes(x, i_bits)          # (I, Kin)
    w_b = weight_bitplanes(w, w_bits)            # (W, Kout, Kin)
    part = jnp.einsum("jc,ikc->ijk", x_b, w_b,
                      preferred_element_type=jnp.int32)
    acc = _recombine(part, w_bits, i_bits)
    scale = scale_ref[...].astype(jnp.int64)
    bias = bias_ref[...].astype(jnp.int64)
    out = normquant(acc.astype(jnp.int64), scale, bias, shift, o_bits)
    o_ref[...] = out.astype(jnp.int32)


def _add_requant_kernel(a_ref, b_ref, o_ref, *, scale_a, scale_b, shift,
                        o_bits):
    """Residual add + requantization (runs on the RISC-V cores on-chip)."""
    a = a_ref[...].astype(jnp.int64)
    b = b_ref[...].astype(jnp.int64)
    v = jnp.right_shift(scale_a * a + scale_b * b, shift)
    o_ref[...] = jnp.clip(v, 0, (1 << o_bits) - 1).astype(jnp.int32)


def _avgpool_kernel(x_ref, o_ref, *, shift):
    """Global average pool: sum over H,W then arithmetic shift (8x8 = 2^6).

    The sum is widened to int64 (matching `ref.avgpool_ref` and the >32-bit
    on-chip accumulation headroom) and cast back to the int32 output ref --
    under `jax_enable_x64` the reduction promotes to int64 either way, and
    an uncast store is a dtype error in pallas.
    """
    x = x_ref[...].astype(jnp.int64)
    s = jnp.sum(x, axis=(0, 1))
    o_ref[...] = jnp.right_shift(s, shift).astype(jnp.int32)


def rbe_conv3x3(x, w, scale, bias, *, w_bits, i_bits, o_bits, shift,
                stride=1):
    """3x3 quantized convolution on an already-padded input.

    x: (H+2p, W+2p, Kin) int32 in [0, 2^i_bits); w: (Kout, Kin, 3, 3) int32
    in [-2^(w_bits-1), 2^(w_bits-1)); returns (Ho, Wo, Kout) int32 in
    [0, 2^o_bits).
    """
    hp, wp, _ = x.shape
    kout = w.shape[0]
    ho = (hp - 3) // stride + 1
    wo = (wp - 3) // stride + 1
    kern = functools.partial(_conv3x3_kernel, w_bits=w_bits, i_bits=i_bits,
                             o_bits=o_bits, shift=shift, stride=stride)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((ho, wo, kout), jnp.int32),
        interpret=True,
    )(x, w, scale, bias)


def rbe_conv1x1(x, w, scale, bias, *, w_bits, i_bits, o_bits, shift,
                stride=1):
    """1x1 (pointwise) quantized convolution.

    x: (H, W, Kin); w: (Kout, Kin); returns (Ho, Wo, Kout).
    """
    h, wd, _ = x.shape
    kout = w.shape[0]
    ho = (h - 1) // stride + 1
    wo = (wd - 1) // stride + 1
    kern = functools.partial(_conv1x1_kernel, w_bits=w_bits, i_bits=i_bits,
                             o_bits=o_bits, shift=shift, stride=stride)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((ho, wo, kout), jnp.int32),
        interpret=True,
    )(x, w, scale, bias)


def rbe_linear(x, w, scale, bias, *, w_bits, i_bits, o_bits, shift):
    """Fully-connected layer: x (Kin,), w (Kout, Kin) -> (Kout,)."""
    kout = w.shape[0]
    kern = functools.partial(_linear_kernel, w_bits=w_bits, i_bits=i_bits,
                             o_bits=o_bits, shift=shift)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((kout,), jnp.int32),
        interpret=True,
    )(x, w, scale, bias)


def add_requant(a, b, *, scale_a, scale_b, shift, o_bits):
    """Residual add with requantization; a, b same shape."""
    kern = functools.partial(_add_requant_kernel, scale_a=scale_a,
                             scale_b=scale_b, shift=shift, o_bits=o_bits)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.int32),
        interpret=True,
    )(a, b)


def avgpool_quant(x, *, shift):
    """Global average pooling via sum + arithmetic shift: (H, W, K) -> (K,)."""
    kern = functools.partial(_avgpool_kernel, shift=shift)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((x.shape[2],), jnp.int32),
        interpret=True,
    )(x)
