"""Pure-numpy oracle for the RBE kernels.

This is the *specification*: a plain signed-integer convolution followed by
Eq. 2 normquant, with none of the bit-serial restructuring.  The Pallas
kernels in `rbe_conv.py` must agree bit-exactly with these functions for
every shape and precision -- that equality is the core L1 correctness
signal (pytest + hypothesis in python/tests/), and the same semantics are
re-implemented a third time in rust (`rbe::functional`) and cross-checked
against the AOT artifacts.
"""

import numpy as np


def _normquant(acc, scale, bias, shift, o_bits):
    v = (scale.astype(np.int64) * acc.astype(np.int64) +
         bias.astype(np.int64)) >> shift
    return np.clip(v, 0, (1 << o_bits) - 1).astype(np.int32)


def conv3x3_ref(x, w, scale, bias, *, o_bits, shift, stride=1):
    """x: (H+2p, W+2p, Kin) unsigned; w: (Kout, Kin, 3, 3) signed."""
    x = np.asarray(x, dtype=np.int64)
    w = np.asarray(w, dtype=np.int64)
    hp, wp, kin = x.shape
    kout = w.shape[0]
    ho = (hp - 3) // stride + 1
    wo = (wp - 3) // stride + 1
    acc = np.zeros((ho, wo, kout), dtype=np.int64)
    for h in range(ho):
        for c in range(wo):
            patch = x[h * stride:h * stride + 3, c * stride:c * stride + 3, :]
            # (3,3,Kin) x (Kout,Kin,3,3) -> Kout
            acc[h, c, :] = np.einsum("yxc,kcyx->k", patch, w)
    return _normquant(acc, np.asarray(scale)[None, None, :],
                      np.asarray(bias)[None, None, :], shift, o_bits)


def conv1x1_ref(x, w, scale, bias, *, o_bits, shift, stride=1):
    """x: (H, W, Kin) unsigned; w: (Kout, Kin) signed."""
    x = np.asarray(x, dtype=np.int64)[::stride, ::stride, :]
    w = np.asarray(w, dtype=np.int64)
    acc = np.einsum("hwc,kc->hwk", x, w)
    return _normquant(acc, np.asarray(scale)[None, None, :],
                      np.asarray(bias)[None, None, :], shift, o_bits)


def linear_ref(x, w, scale, bias, *, o_bits, shift):
    """x: (Kin,) unsigned; w: (Kout, Kin) signed."""
    acc = np.asarray(w, dtype=np.int64) @ np.asarray(x, dtype=np.int64)
    return _normquant(acc, np.asarray(scale), np.asarray(bias), shift, o_bits)


def add_requant_ref(a, b, *, scale_a, scale_b, shift, o_bits):
    v = (np.asarray(a, dtype=np.int64) * scale_a +
         np.asarray(b, dtype=np.int64) * scale_b) >> shift
    return np.clip(v, 0, (1 << o_bits) - 1).astype(np.int32)


def avgpool_ref(x, *, shift):
    s = np.sum(np.asarray(x, dtype=np.int64), axis=(0, 1))
    return (s >> shift).astype(np.int32)
