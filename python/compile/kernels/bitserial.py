"""Bit-decomposition helpers shared by the RBE Pallas kernels.

The RBE (paper SS II-B) computes a W-bit x I-bit product as W*I single-bit
AND contributions, scaled by powers of two and accumulated in 32-bit
registers (Eq. 1).  Activations are unsigned I-bit; weights are *signed*
W-bit in two's complement, which bit-serial hardware realizes by giving the
weight MSB plane a negative scale (-2^(W-1) instead of +2^(W-1)).  These
helpers express exactly that decomposition in jnp so the Pallas kernel's
arithmetic mirrors the datapath gate-for-gate.
"""

import jax.numpy as jnp
import numpy as np


def unsigned_bitplanes(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Stack the `bits` LSB planes of unsigned `x` along a new axis 0.

    x: int32 tensor with values in [0, 2^bits).  Returns (bits, *x.shape)
    int32 tensor of 0/1 values — the hardware's input bit streams.
    """
    planes = [(x >> j) & 1 for j in range(bits)]
    return jnp.stack(planes, axis=0)


def weight_bitplanes(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Bit planes of signed two's-complement `w` (values in [-2^(b-1), 2^(b-1))).

    Planes are of the *unsigned offset pattern* (w & mask); the sign is
    reintroduced by `bit_coefficients`, which weights the MSB plane
    negatively.  Returns (bits, *w.shape) of 0/1 int32.
    """
    wu = w & ((1 << bits) - 1)  # two's-complement pattern as unsigned
    planes = [(wu >> i) & 1 for i in range(bits)]
    return jnp.stack(planes, axis=0)


def bit_coefficients(w_bits: int, i_bits: int) -> np.ndarray:
    """coef[i, j] = (+|-)2^(i+j): the Eq. 1 shift factor for weight-bit i and
    input-bit j, with the weight MSB plane negative (two's complement)."""
    coef = np.zeros((w_bits, i_bits), dtype=np.int64)
    for i in range(w_bits):
        sign = -1 if i == w_bits - 1 and w_bits > 1 else 1
        for j in range(i_bits):
            coef[i, j] = sign * (1 << (i + j))
    return coef


def normquant(acc: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              shift: int, o_bits: int) -> jnp.ndarray:
    """Eq. 2 + ReLU: out = clip((scale*acc + bias) >> shift, 0, 2^O - 1).

    scale/bias are per-output-channel int32 (broadcast over leading dims);
    the right shift is arithmetic, exactly as the RBE Quantizer.
    """
    v = scale * acc + bias
    v = jnp.right_shift(v, shift)  # arithmetic shift on signed int32
    return jnp.clip(v, 0, (1 << o_bits) - 1)
