"""Make the `compile` package importable when pytest runs from the repo
root (`python -m pytest python/tests -q`): tests import `compile.model`
etc. relative to this directory."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
