//! In-tree, dependency-free subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so the workspace vendors
//! the small slice of `anyhow` the simulator actually uses as a path
//! crate: [`Error`], [`Result`], the [`Context`] extension trait (on both
//! `Result` and `Option`), and the [`anyhow!`], [`bail!`] and [`ensure!`]
//! macros. Error causes are flattened into a single `: `-joined message
//! chain rather than kept as a walkable source chain — enough for CLI
//! reporting and test assertions, and drop-in replaceable by the real
//! crate (same names, same call sites) if the registry ever becomes
//! available.

use std::fmt;

/// A flattened error: the context chain joined into one message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Wrap with an outer context message (`context: inner`).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent alongside core's reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures (`Result`) or absences (`Option`).
pub trait Context<T> {
    /// Wrap the error/none case with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error/none case with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "Condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::Other, "boom");
        Err(e)?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("boom"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));

        let o: Option<i32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_build_messages() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(
            f(-1).unwrap_err().to_string(),
            "x must be positive, got -1"
        );
        assert_eq!(f(101).unwrap_err().to_string(), "too big");
        let e = anyhow!("plain {} message", 1);
        assert_eq!(e.to_string(), "plain 1 message");
    }

    #[test]
    fn ensure_without_message_names_the_condition() {
        fn f() -> Result<()> {
            let v = [1, 2];
            ensure!(v.len() == 3);
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("Condition failed"));
    }
}
