//! Compile-time stub of the `xla` crate's PJRT surface.
//!
//! The Marsellus runtime's PJRT backend (cargo feature `pjrt`) is written
//! against the real `xla` bindings (PJRT CPU client + HLO-text
//! compilation). That crate links a native XLA toolchain which is not
//! available in this build environment, so this stub keeps the `pjrt`
//! feature *compiling* everywhere: every entry point type-checks, and the
//! single constructor ([`PjRtClient::cpu`]) fails with an explanatory
//! error, so nothing downstream ever executes.
//!
//! To run real PJRT artifacts, point cargo at the actual bindings in the
//! workspace root:
//!
//! ```toml
//! [patch.crates-io]           # or a [patch."…"] for a git source
//! xla = { git = "https://github.com/LaurentMazare/xla-rs" }
//! ```
//!
//! The API subset below mirrors exactly what `runtime/pjrt.rs` calls.

use std::fmt;

/// Error type standing in for the real crate's `xla::Error`.
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

fn stub_err<T>() -> Result<T, Error> {
    Err(Error(
        "xla stub: built against the in-tree `vendor/xla` placeholder; \
         patch in the real xla crate to execute PJRT artifacts"
            .to_string(),
    ))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        stub_err()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        stub_err()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        stub_err()
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Compiled executable (stub: unreachable, the client never constructs).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        stub_err()
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        stub_err()
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[i32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        stub_err()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        stub_err()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        stub_err()
    }
}
