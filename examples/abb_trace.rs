//! Adaptive-body-biasing demo: runs the paper's Fig. 11 three-phase
//! synthetic benchmark at the 470 MHz overclocked operating point, with
//! and without ABB, and prints the bias/pre-error trace plus the Fig. 12
//! transition detail.
//!
//! ```sh
//! cargo run --release --example abb_trace [--vdd 0.8] [--freq 470]
//! ```

use anyhow::Result;
use marsellus::abb::{AbbSim, Phase};
use marsellus::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let vdd = args.get_f64("vdd", 0.8)?;
    let freq = args.get_f64("freq", 470.0)?;

    println!("== with ABB ==");
    let mut sim = AbbSim::new(vdd, freq, true);
    let res = sim.run(&Phase::fig11_benchmark(), 20.0);
    for p in &res.trace {
        let bar_len = (p.fbb_v * 40.0) as usize;
        println!(
            "t={:>6.1}µs  {:<16}  V_FBB={:.3} |{:<36}| pre={:<3} real={}",
            p.t_us,
            p.phase,
            p.fbb_v,
            "#".repeat(bar_len),
            p.pre_errors,
            p.real_errors
        );
    }
    println!(
        "boost events = {} (paper: 2); pre-errors = {}; real errors = {} \
         (paper: errorless); avg power = {:.1} mW",
        res.boost_events,
        res.total_pre_errors,
        res.total_real_errors,
        res.avg_power_mw
    );

    println!("\n== without ABB (bias generator frozen) ==");
    let mut sim = AbbSim::new(vdd, freq, false);
    let res = sim.run(&Phase::fig11_benchmark(), 100.0);
    println!(
        "real errors = {} -> the overclocked point is NOT functional \
         without ABB",
        res.total_real_errors
    );

    println!("\n== Fig. 12 transition detail ==");
    println!("{}", marsellus::figures::fig12());
    Ok(())
}
