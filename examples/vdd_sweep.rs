//! Voltage/frequency/power sweep: regenerates Fig. 9 (f_max & power vs
//! V_DD), Fig. 10 (fixed-frequency undervolting with ABB) and Fig. 15
//! (efficiency vs performance) from the calibrated models + ISS.
//!
//! ```sh
//! cargo run --release --example vdd_sweep [--fast]
//! ```

use anyhow::Result;
use marsellus::util::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let fast = args.flag("fast");
    println!("{}\n", marsellus::figures::fig9());
    println!("{}\n", marsellus::figures::fig10());
    println!("{}", marsellus::figures::fig15(fast)?);
    Ok(())
}
