//! Quickstart: offload one convolution to the (simulated) RBE, get the
//! functional result through the execution backend (native by default —
//! no artifacts needed; set `MARSELLUS_BACKEND=pjrt` after `make
//! artifacts` for the PJRT path), and read the cycle/power estimates
//! from the calibrated models.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use marsellus::power::{OperatingPoint, PowerModel, Workload};
use marsellus::rbe::functional::{conv_bitserial, NormQuant};
use marsellus::rbe::{RbeJob, RbeTiming};
use marsellus::runtime::{Runtime, TensorArg};
use marsellus::util::{Args, Rng};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let rt = Runtime::cpu(Runtime::resolve_artifacts_dir(args.get("artifacts")))?;
    println!("backend: {} ({})", rt.kind().as_str(), rt.platform());

    // The quickstart artifact: 16x16x32 -> 32 channels, 3x3, W4/I4/O4.
    let (h, cin, cout, bits, shift) = (16usize, 32usize, 32usize, 4usize, 10);
    let name = format!(
        "conv3x3_h{h}_ci{cin}_co{cout}_s1_w{bits}i{bits}o{bits}"
    );
    let exe = rt.load(&name)?;

    let mut rng = Rng::new(7);
    let hp = h + 2;
    let x: Vec<i32> =
        (0..hp * hp * cin).map(|_| rng.range_i32(0, 16)).collect();
    let w: Vec<i32> =
        (0..cout * cin * 9).map(|_| rng.range_i32(-8, 8)).collect();
    let scale: Vec<i32> = (0..cout).map(|_| rng.range_i32(1, 16)).collect();
    let bias: Vec<i32> = (0..cout).map(|_| rng.range_i32(-500, 500)).collect();

    // 1) functional result via the execution backend (native RBE model,
    //    or the L1 Pallas kernel AOT-compiled to HLO under PJRT)
    let out = exe.execute_i32(&[
        TensorArg::new(x.clone(), vec![hp, hp, cin]),
        TensorArg::new(w.clone(), vec![cout, cin, 3, 3]),
        TensorArg::scalar_vec(scale.clone()),
        TensorArg::scalar_vec(bias.clone()),
    ])?;
    println!("artifact {name}: output {} values", out[0].len());

    // 2) cross-check against the Rust bit-serial datapath model (Eq. 1-2)
    let job = RbeJob::conv3x3(h, h, cin, cout, 1, bits, bits, bits)?;
    let nq = NormQuant::new(scale, bias, shift);
    let ours = conv_bitserial(&job, &x, &w, &nq)?;
    assert_eq!(ours, out[0], "bit-serial model vs backend result");
    println!("bit-exact against the Rust bit-serial RBE model ✓");

    // 3) timing + power at the nominal operating point
    let phases = RbeTiming::phases(&job);
    let op = OperatingPoint::nominal();
    let p = PowerModel.total_mw(Workload::Rbe { duty_pct: 100 }, &op);
    let us = phases.total() as f64 / op.freq_mhz;
    println!(
        "RBE estimate @{:.2} V/{:.0} MHz: {} cycles ({:.1} µs), {:.1} mW, \
         {:.1} Gop/s",
        op.vdd,
        op.freq_mhz,
        phases.total(),
        us,
        p,
        job.ops() as f64 / us / 1.0e3
    );
    println!(
        "  phases: setup {} load {} compute {} normquant {} streamout {}",
        phases.setup, phases.load, phases.compute, phases.normquant,
        phases.streamout
    );
    Ok(())
}
