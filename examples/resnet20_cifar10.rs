//! End-to-end driver (DESIGN.md §End-to-end validation): run full
//! ResNet-20/CIFAR-10 inferences through the three-layer stack —
//! functional numerics from the execution backend (native RBE models by
//! default, AOT Pallas artifacts under `MARSELLUS_BACKEND=pjrt`), timing
//! and energy from the calibrated SoC simulator — in both precision
//! configurations and at several operating points, reproducing the
//! paper's Figs. 17–18 rows for this workload. The network is deployed
//! once (`Coordinator::deploy`) and the batch fans out over worker
//! threads via `Deployment::infer_batch`.
//!
//! ```sh
//! cargo run --release --example resnet20_cifar10 [--batch N] [--threads T]
//! ```

use anyhow::Result;
use marsellus::coordinator::{random_image, Coordinator};
use marsellus::dnn::{NetworkSpec, PrecisionConfig};
use marsellus::power::{OperatingPoint, FBB_MAX_V};
use marsellus::util::{Args, Rng};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let dir = marsellus::runtime::Runtime::resolve_artifacts_dir(args.get("artifacts"));
    let coord = Coordinator::new(dir)?;
    let batch = args.get_usize("batch", 4)?;
    anyhow::ensure!(batch >= 1, "--batch must be >= 1, got {batch}");
    let threads = args.get_usize("threads", 4)?;

    let points = [
        ("0.80 V", OperatingPoint::at_vdd(0.8)),
        (
            "0.65 V + ABB",
            OperatingPoint { vdd: 0.65, freq_mhz: 400.0, fbb_v: FBB_MAX_V },
        ),
        ("0.50 V", OperatingPoint::at_vdd(0.5)),
    ];

    for config in [PrecisionConfig::Uniform8, PrecisionConfig::Mixed] {
        println!("=== ResNet-20/CIFAR-10, {} ===", config.as_str());
        let mut rng = Rng::new(2024);

        // image 0 runs solo with in-flight cross-checking against the
        // Rust bit-serial datapath model ...
        // fixed weight seed across the batch: one deployment
        let deployment =
            coord.deploy(&NetworkSpec::new("resnet20", config, 42))?;
        let image0 = random_image(8, &mut rng);
        let res0 = deployment.infer_cross_checked(
            &OperatingPoint::at_vdd(0.8),
            &image0,
            &["stage3.b2.conv1", "stage2.b0.down"],
        )?;
        println!(
            "image 0 logits: {:?} (cross-checked {} layers bit-exactly \
             vs the Rust RBE datapath model)",
            res0.logits, res0.cross_checked
        );

        // ... then the full batch fans out over worker threads sharing
        // the runtime (image 0 again first: logits must be identical).
        let mut images = vec![image0];
        images.extend((1..batch).map(|_| random_image(8, &mut rng)));
        let results = deployment.infer_batch(
            &OperatingPoint::at_vdd(0.8),
            &images,
            threads,
        )?;
        assert_eq!(results[0].logits, res0.logits, "batch-of-1 vs batch-of-N");
        let logits_acc: i64 = results
            .iter()
            .flat_map(|r| r.logits.iter())
            .map(|&v| v as i64)
            .sum();
        println!(
            "batch of {} on {threads} thread(s) done (logit checksum {logits_acc})",
            images.len()
        );
        for (name, op) in &points {
            let res =
                deployment.infer(op, &random_image(8, &mut Rng::new(1)))?;
            println!(
                "  {name:>13}: latency {:>8.0} µs  energy {:>7.1} µJ  \
                 {:>6.2} Top/s/W  {:>6.1} Gop/s",
                res.report.total_latency_us(),
                res.report.total_energy_uj(),
                res.report.tops_per_w(),
                res.report.gops(),
            );
        }
        println!();
    }
    println!("(paper anchors: 8-bit ~87 µJ -> mixed ~28 µJ @0.8 V; \
              ~21 µJ @0.65 V+ABB; ~12 µJ @0.5 V; 1.05 ms @0.5 V)");
    Ok(())
}
