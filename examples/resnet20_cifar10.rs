//! End-to-end driver (DESIGN.md §End-to-end validation): run full
//! ResNet-20/CIFAR-10 inferences through the three-layer stack —
//! functional numerics from the AOT Pallas artifacts via PJRT, timing and
//! energy from the calibrated SoC simulator — in both precision
//! configurations and at several operating points, reproducing the
//! paper's Figs. 17–18 rows for this workload.
//!
//! ```sh
//! make artifacts && cargo run --release --example resnet20_cifar10
//! ```

use anyhow::Result;
use marsellus::coordinator::{random_image, Coordinator};
use marsellus::dnn::PrecisionConfig;
use marsellus::power::{OperatingPoint, FBB_MAX_V};
use marsellus::util::{Args, Rng};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let coord = Coordinator::new(args.get_or("artifacts", "artifacts"))?;
    let batch = args.get_usize("batch", 4)?;

    let points = [
        ("0.80 V", OperatingPoint::at_vdd(0.8)),
        (
            "0.65 V + ABB",
            OperatingPoint { vdd: 0.65, freq_mhz: 400.0, fbb_v: FBB_MAX_V },
        ),
        ("0.50 V", OperatingPoint::at_vdd(0.5)),
    ];

    for config in [PrecisionConfig::Uniform8, PrecisionConfig::Mixed] {
        println!("=== ResNet-20/CIFAR-10, {} ===", config.as_str());
        let mut rng = Rng::new(2024);
        let mut logits_acc = 0i64;
        for img in 0..batch {
            let image = random_image(8, &mut rng);
            let res = coord.infer_resnet20(
                config,
                &OperatingPoint::at_vdd(0.8),
                &image,
                42, // fixed weights across the batch
                if img == 0 { &["stage3.b2.conv1", "stage2.b0.down"] }
                else { &[] },
            )?;
            logits_acc += res.logits.iter().map(|&v| v as i64).sum::<i64>();
            if img == 0 {
                println!(
                    "image 0 logits: {:?} (cross-checked {} layers \
                     bit-exactly vs the Rust RBE datapath model)",
                    res.logits, res.cross_checked
                );
            }
        }
        println!("batch of {batch} done (logit checksum {logits_acc})");
        for (name, op) in &points {
            let res = coord.infer_resnet20(
                config,
                op,
                &random_image(8, &mut Rng::new(1)),
                42,
                &[],
            )?;
            println!(
                "  {name:>13}: latency {:>8.0} µs  energy {:>7.1} µJ  \
                 {:>6.2} Top/s/W  {:>6.1} Gop/s",
                res.report.total_latency_us(),
                res.report.total_energy_uj(),
                res.report.tops_per_w(),
                res.report.gops(),
            );
        }
        println!();
    }
    println!("(paper anchors: 8-bit ~87 µJ -> mixed ~28 µJ @0.8 V; \
              ~21 µJ @0.65 V+ABB; ~12 µJ @0.5 V; 1.05 ms @0.5 V)");
    Ok(())
}
